//! Request queue and coalescing worker pool — the live (wall-clock)
//! serving path.
//!
//! Producers push `y = A x` requests; workers pop the oldest request
//! together with the other pending requests against the *same* matrix
//! (up to `max_batch`) and execute the group as one multi-vector
//! SpMM. The queue indexes pending requests per matrix id, so a
//! coalescing pop is O(batch) instead of rebuilding the whole backlog
//! each time, and it can be constructed with a bounded capacity for
//! admission control ([`RequestQueue::bounded`] + [`RequestQueue::try_push`]).
//!
//! Kernel dispatch mode is the engine's: a pooled [`super::ServeEngine`]
//! runs every drained batch on its persistent `exec::ExecPool` workers
//! (no per-request thread spawn), a spawn-mode engine falls back to
//! scoped threads — the drain loop is identical either way.
//!
//! Worker faults are data, not crashes: a request against an
//! unregistered matrix id (or with a wrong-length vector) is counted
//! in telemetry as an error outcome and the pool keeps serving.
//! Deterministic replay (virtual time) lives in [`super::replay`];
//! this module is real concurrency for the `serve-bench` CLI, the
//! sharded server in [`super::shard`], and the throughput bench.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::Ordering;

use crate::util::ordatomic::OrdAtomicUsize;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use super::ServeEngine;

/// One enqueued `y = A x` request. The input vector is shared so many
/// requests against the same matrix can reuse one allocation.
pub struct Request {
    pub matrix_id: usize,
    pub x: Arc<Vec<f64>>,
    pub submitted: Instant,
}

impl Request {
    pub fn new(matrix_id: usize, x: impl Into<Arc<Vec<f64>>>) -> Self {
        Request { matrix_id, x: x.into(), submitted: Instant::now() }
    }
}

/// Why an admission attempt was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PushError {
    /// The queue has been closed.
    Closed,
    /// A bounded queue is at capacity — backpressure.
    Full,
}

#[derive(Default)]
struct QueueInner {
    /// Arrival order of every admitted request: `(seq, matrix_id)`.
    /// Entries whose request was already consumed by an earlier
    /// coalesced batch are skipped lazily on pop (each entry is
    /// discarded at most once, so pops stay amortized O(batch)).
    order: VecDeque<(u64, usize)>,
    /// Pending requests per matrix id, FIFO within a matrix.
    by_matrix: HashMap<usize, VecDeque<(u64, Request)>>,
    len: usize,
    next_seq: u64,
    closed: bool,
}

/// Thread-safe FIFO with same-matrix coalescing pops and optional
/// bounded capacity.
#[derive(Default)]
pub struct RequestQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
    /// 0 = unbounded.
    cap: usize,
}

impl RequestQueue {
    /// Unbounded queue (pushes never observe backpressure).
    pub fn new() -> Self {
        Self::default()
    }

    /// Bounded queue: at most `cap` pending requests; `try_push`
    /// returns [`PushError::Full`] beyond that. `cap == 0` means
    /// unbounded.
    pub fn bounded(cap: usize) -> Self {
        RequestQueue { cap, ..Self::default() }
    }

    /// The configured capacity (0 = unbounded).
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Lock the queue state, recovering from poison: the inner state
    /// is only touched by short panic-free sections, so it stays
    /// consistent even if a peer thread died mid-serve.
    fn state(&self) -> std::sync::MutexGuard<'_, QueueInner> {
        self.inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Non-blocking admission: enqueue or report why not. Rejected
    /// requests are dropped (the caller accounts for them).
    pub fn try_push(&self, req: Request) -> Result<(), PushError> {
        let mut inner = self.state();
        if inner.closed {
            return Err(PushError::Closed);
        }
        if self.cap > 0 && inner.len >= self.cap {
            return Err(PushError::Full);
        }
        let seq = inner.next_seq;
        inner.next_seq += 1;
        inner.order.push_back((seq, req.matrix_id));
        inner
            .by_matrix
            .entry(req.matrix_id)
            .or_default()
            .push_back((seq, req));
        inner.len += 1;
        drop(inner);
        self.cv.notify_one();
        Ok(())
    }

    /// Infallible push for unbounded queues; panics after `close` or
    /// on a full bounded queue (use [`Self::try_push`] there).
    pub fn push(&self, req: Request) {
        match self.try_push(req) {
            Ok(()) => {}
            Err(PushError::Closed) => panic!("push after close"),
            Err(PushError::Full) => {
                panic!("push to a full bounded queue (use try_push)")
            }
        }
    }

    /// No more pushes; blocked poppers drain and then observe `None`.
    pub fn close(&self) {
        self.state().closed = true;
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state().len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Pop the oldest request plus up to `max_batch - 1` later
    /// requests against the same matrix (FIFO order preserved).
    /// Blocks while the queue is open and empty; returns `None` once
    /// closed and drained.
    pub fn pop_batch(&self, max_batch: usize) -> Option<Vec<Request>> {
        let max_batch = max_batch.max(1);
        let mut inner = self.state();
        loop {
            while let Some(&(seq, mid)) = inner.order.front() {
                let live = inner
                    .by_matrix
                    .get(&mid)
                    .and_then(|q| q.front())
                    .is_some_and(|&(s, _)| s == seq);
                if !live {
                    // Consumed by an earlier coalesced batch.
                    inner.order.pop_front();
                    continue;
                }
                inner.order.pop_front();
                // `live` above proved this queue exists and its head
                // matches `seq`. lint:allow(no-unwrap)
                let q = inner.by_matrix.get_mut(&mid).expect("live head");
                let take = q.len().min(max_batch);
                let mut batch = Vec::with_capacity(take);
                for _ in 0..take {
                    // `take <= q.len()`. lint:allow(no-unwrap)
                    batch.push(q.pop_front().expect("within q.len()").1);
                }
                if q.is_empty() {
                    inner.by_matrix.remove(&mid);
                }
                inner.len -= take;
                return Some(batch);
            }
            if inner.closed {
                return None;
            }
            inner = self
                .cv
                .wait(inner)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }
}

/// One worker loop: drain `queue` into `engine` until closed and
/// empty. Successful requests land latency samples and bump `served`;
/// requests past `deadline_ms` (0 = no deadline) are shed; execution
/// failures (unregistered matrix id, wrong vector length) are counted
/// as error outcomes — the worker never panics on bad traffic.
pub(crate) fn drain_worker(
    engine: &ServeEngine,
    queue: &RequestQueue,
    max_batch: usize,
    deadline_ms: f64,
    served: &OrdAtomicUsize,
) {
    while let Some(mut batch) = queue.pop_batch(max_batch) {
        if deadline_ms > 0.0 {
            let now = Instant::now();
            let before = batch.len();
            batch.retain(|r| {
                now.duration_since(r.submitted).as_secs_f64() * 1e3
                    <= deadline_ms
            });
            let shed = before - batch.len();
            if shed > 0 {
                engine.telemetry.record_shed(shed as u64);
            }
            if batch.is_empty() {
                continue;
            }
        }
        // Queue wait (enqueue stamp -> dispatch) is accounted apart
        // from service time: a fast kernel behind a deep backlog and a
        // slow kernel on an idle queue are different problems.
        let t_dispatch = Instant::now();
        for r in &batch {
            let wait_ms =
                t_dispatch.duration_since(r.submitted).as_secs_f64() * 1e3;
            engine.telemetry.record_queue_wait_ms(wait_ms);
            if let Some(rec) = engine.trace() {
                rec.record_elapsed(
                    0,
                    crate::obs::Stage::QueueWait,
                    crate::obs::trace::SCHED_NONE,
                    wait_ms * 1e3,
                );
            }
        }
        let id = batch[0].matrix_id;
        // Serving discards outputs, so the drain loop rides the
        // engine's scratch-arena path (`serve_batch`) — no per-request
        // output materialization, no per-dispatch result vectors.
        let xs: Vec<&[f64]> = batch.iter().map(|r| r.x.as_slice()).collect();
        match engine.serve_batch(id, &xs) {
            Ok(_) => {
                let done = Instant::now();
                for r in &batch {
                    engine.telemetry.record_latency_ms(
                        done.duration_since(r.submitted).as_secs_f64() * 1e3,
                    );
                }
                // ord: Relaxed RMW — served tally; the caller reads it
                // with into_inner after the worker scope joins.
                served.fetch_add(batch.len(), Ordering::Relaxed);
            }
            Err(_) if batch.len() > 1 => {
                // One poison request (wrong vector length) failed the
                // coalesced dispatch; isolate it by retrying singly so
                // the valid co-batched requests still get answers.
                for r in &batch {
                    match engine.serve_batch(id, &[r.x.as_slice()]) {
                        Ok(_) => {
                            engine.telemetry.record_latency_ms(
                                r.submitted.elapsed().as_secs_f64() * 1e3,
                            );
                            // ord: Relaxed RMW — served tally (see
                            // the batch-success arm above).
                            served.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(_) => engine.telemetry.record_errors(1),
                    }
                }
            }
            Err(_) => {
                engine.telemetry.record_errors(1);
            }
        }
    }
}

/// Drain `queue` with `workers` threads executing coalesced batches
/// on `engine` until the queue is closed and empty. Latencies
/// (submit → batch completion, wall clock) and batch stats land in
/// the engine's telemetry; failed requests are counted there as
/// errors instead of panicking the pool. Returns the number of
/// requests served successfully.
pub fn serve_queue(
    engine: &ServeEngine,
    queue: &RequestQueue,
    workers: usize,
    max_batch: usize,
) -> usize {
    let served = OrdAtomicUsize::named(0, "batch.served");
    std::thread::scope(|s| {
        for _ in 0..workers.max(1) {
            let served = &served;
            s.spawn(move || {
                drain_worker(engine, queue, max_batch, 0.0, served);
            });
        }
    });
    served.into_inner()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize) -> Request {
        Request::new(id, vec![0.0])
    }

    /// Request whose payload encodes a producer-side sequence number
    /// in `x[0]`, so tests can assert FIFO order per matrix.
    fn seq_req(id: usize, seq: usize) -> Request {
        Request::new(id, vec![seq as f64])
    }

    fn seq_of(r: &Request) -> usize {
        r.x[0] as usize
    }

    #[test]
    fn pop_batch_coalesces_same_matrix() {
        let q = RequestQueue::new();
        for id in [7, 7, 3, 7, 3] {
            q.push(req(id));
        }
        q.close();
        let b1 = q.pop_batch(8).unwrap();
        assert_eq!(b1.iter().map(|r| r.matrix_id).collect::<Vec<_>>(), [7; 3]);
        let b2 = q.pop_batch(8).unwrap();
        assert_eq!(b2.iter().map(|r| r.matrix_id).collect::<Vec<_>>(), [3; 2]);
        assert!(q.pop_batch(8).is_none());
    }

    #[test]
    fn pop_batch_respects_max() {
        let q = RequestQueue::new();
        for _ in 0..5 {
            q.push(req(1));
        }
        q.close();
        assert_eq!(q.pop_batch(2).unwrap().len(), 2);
        assert_eq!(q.pop_batch(2).unwrap().len(), 2);
        assert_eq!(q.pop_batch(2).unwrap().len(), 1);
        assert!(q.pop_batch(2).is_none());
    }

    #[test]
    fn close_unblocks_poppers() {
        let q = RequestQueue::new();
        std::thread::scope(|s| {
            let h = s.spawn(|| q.pop_batch(4));
            std::thread::sleep(std::time::Duration::from_millis(10));
            q.close();
            assert!(h.join().unwrap().is_none());
        });
    }

    #[test]
    fn bounded_queue_applies_backpressure() {
        let q = RequestQueue::bounded(3);
        assert_eq!(q.capacity(), 3);
        for i in 0..3 {
            assert_eq!(q.try_push(seq_req(0, i)), Ok(()));
        }
        assert_eq!(q.try_push(seq_req(0, 3)), Err(PushError::Full));
        assert_eq!(q.len(), 3);
        // Popping frees capacity again.
        let b = q.pop_batch(2).unwrap();
        assert_eq!(b.len(), 2);
        assert_eq!(q.try_push(seq_req(1, 4)), Ok(()));
        q.close();
        assert_eq!(q.try_push(seq_req(1, 5)), Err(PushError::Closed));
        // Close with backlog: everything still pending drains.
        let drained: usize =
            std::iter::from_fn(|| q.pop_batch(8)).map(|b| b.len()).sum();
        assert_eq!(drained, 2);
        assert!(q.is_empty());
    }

    #[test]
    fn single_consumer_sees_fifo_per_matrix_and_drains_backlog() {
        // Deep interleaved backlog, closed before any pop: the queue
        // must drain completely, every batch single-matrix, FIFO
        // within each matrix across batches, ceilings respected.
        let q = RequestQueue::new();
        let (matrices, per) = (5usize, 200usize);
        let mut pushed = vec![0usize; matrices];
        for i in 0..matrices * per {
            let id = (i * 7 + i / 3) % matrices; // deterministic shuffle
            q.push(seq_req(id, pushed[id]));
            pushed[id] += 1;
        }
        q.close();
        let mut next = vec![0usize; matrices];
        let mut total = 0usize;
        while let Some(batch) = q.pop_batch(8) {
            assert!(!batch.is_empty() && batch.len() <= 8);
            let id = batch[0].matrix_id;
            for r in &batch {
                assert_eq!(r.matrix_id, id, "mixed-matrix batch");
                assert_eq!(seq_of(r), next[id], "FIFO violated for {id}");
                next[id] += 1;
            }
            total += batch.len();
        }
        assert_eq!(total, matrices * per, "close-with-backlog must drain");
        assert_eq!(next, pushed);
        assert!(q.is_empty());
    }

    #[test]
    fn mpmc_stress_preserves_batching_invariants() {
        // 4 producers x 4 consumers over 3 matrices with a deep
        // backlog: every request is popped exactly once, batches never
        // mix matrices or exceed max_batch, and within a batch each
        // producer's requests appear in the order it pushed them
        // (per-matrix FIFO as observed through one coalesced pop).
        let q = RequestQueue::new();
        let (producers, per_producer, matrices) = (4usize, 500usize, 3usize);
        let max_batch = 8usize;
        let popped: Mutex<Vec<Vec<(usize, usize)>>> = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            let prod: Vec<_> = (0..producers)
                .map(|p| {
                    let q = &q;
                    s.spawn(move || {
                        for i in 0..per_producer {
                            let id = (p + i) % matrices;
                            // Globally unique tag per request.
                            q.push(seq_req(id, p * per_producer + i));
                        }
                    })
                })
                .collect();
            for _ in 0..4 {
                let q = &q;
                let popped = &popped;
                s.spawn(move || {
                    while let Some(batch) = q.pop_batch(max_batch) {
                        let rows: Vec<(usize, usize)> = batch
                            .iter()
                            .map(|r| (r.matrix_id, seq_of(r)))
                            .collect();
                        popped.lock().unwrap().push(rows);
                    }
                });
            }
            for h in prod {
                h.join().unwrap();
            }
            q.close();
        });
        let popped = popped.into_inner().unwrap();
        let mut seen_per_matrix: Vec<Vec<usize>> = vec![Vec::new(); matrices];
        let mut total = 0usize;
        for batch in &popped {
            assert!(!batch.is_empty() && batch.len() <= max_batch);
            let id = batch[0].0;
            let mut last_of: Vec<Option<usize>> = vec![None; producers];
            for &(mid, tag) in batch {
                assert_eq!(mid, id, "mixed-matrix batch");
                let p = tag / per_producer;
                if let Some(prev) = last_of[p] {
                    assert!(
                        tag > prev,
                        "producer {p} order broken within a batch"
                    );
                }
                last_of[p] = Some(tag);
                seen_per_matrix[mid].push(tag);
                total += 1;
            }
        }
        assert_eq!(total, producers * per_producer, "requests lost or duped");
        for (mid, seen) in seen_per_matrix.iter_mut().enumerate() {
            seen.sort_unstable();
            seen.dedup();
            let expect: usize = (0..producers)
                .map(|p| {
                    (0..per_producer)
                        .filter(|i| (p + i) % matrices == mid)
                        .count()
                })
                .sum();
            assert_eq!(
                seen.len(),
                expect,
                "matrix {mid} request multiset wrong"
            );
        }
    }
}
