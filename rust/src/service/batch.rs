//! Request queue and coalescing worker pool — the live (wall-clock)
//! serving path.
//!
//! Producers push `y = A x` requests; workers pop the oldest request
//! together with every other pending request against the *same*
//! matrix (up to `max_batch`) and execute the group as one
//! multi-vector SpMM. Deterministic replay (virtual time) lives in
//! [`super::replay`]; this module is real concurrency for the
//! `serve-bench` CLI and the throughput bench.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use super::ServeEngine;

/// One enqueued `y = A x` request. The input vector is shared so many
/// requests against the same matrix can reuse one allocation.
pub struct Request {
    pub matrix_id: usize,
    pub x: Arc<Vec<f64>>,
    pub submitted: Instant,
}

impl Request {
    pub fn new(matrix_id: usize, x: impl Into<Arc<Vec<f64>>>) -> Self {
        Request { matrix_id, x: x.into(), submitted: Instant::now() }
    }
}

#[derive(Default)]
struct QueueInner {
    deque: VecDeque<Request>,
    closed: bool,
}

/// Thread-safe FIFO with same-matrix coalescing pops.
#[derive(Default)]
pub struct RequestQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
}

impl RequestQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&self, req: Request) {
        let mut inner = self.inner.lock().unwrap();
        assert!(!inner.closed, "push after close");
        inner.deque.push_back(req);
        drop(inner);
        self.cv.notify_one();
    }

    /// No more pushes; blocked poppers drain and then observe `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().deque.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().deque.is_empty()
    }

    /// Pop the oldest request plus up to `max_batch - 1` later
    /// requests against the same matrix (FIFO order preserved).
    /// Blocks while the queue is open and empty; returns `None` once
    /// closed and drained.
    pub fn pop_batch(&self, max_batch: usize) -> Option<Vec<Request>> {
        let max_batch = max_batch.max(1);
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(first) = inner.deque.pop_front() {
                let wanted = first.matrix_id;
                let mut batch = vec![first];
                let mut rest = VecDeque::with_capacity(inner.deque.len());
                while let Some(r) = inner.deque.pop_front() {
                    if r.matrix_id == wanted && batch.len() < max_batch {
                        batch.push(r);
                    } else {
                        rest.push_back(r);
                    }
                }
                inner.deque = rest;
                return Some(batch);
            }
            if inner.closed {
                return None;
            }
            inner = self.cv.wait(inner).unwrap();
        }
    }
}

/// Drain `queue` with `workers` threads executing coalesced batches
/// on `engine` until the queue is closed and empty. Latencies
/// (submit → batch completion, wall clock) and batch stats land in
/// the engine's telemetry. Returns the number of requests served.
pub fn serve_queue(
    engine: &ServeEngine,
    queue: &RequestQueue,
    workers: usize,
    max_batch: usize,
) -> usize {
    let served = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers.max(1) {
            s.spawn(|| {
                while let Some(batch) = queue.pop_batch(max_batch) {
                    let id = batch[0].matrix_id;
                    let xs: Vec<&[f64]> =
                        batch.iter().map(|r| r.x.as_slice()).collect();

                    engine
                        .execute_batch(id, &xs)
                        .expect("registered matrix id");
                    let done = Instant::now();
                    for r in &batch {
                        engine.telemetry.record_latency_ms(
                            done.duration_since(r.submitted).as_secs_f64()
                                * 1e3,
                        );
                    }
                    served.fetch_add(
                        batch.len(),
                        std::sync::atomic::Ordering::Relaxed,
                    );
                }
            });
        }
    });
    served.into_inner()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize) -> Request {
        Request::new(id, vec![0.0])
    }

    #[test]
    fn pop_batch_coalesces_same_matrix() {
        let q = RequestQueue::new();
        for id in [7, 7, 3, 7, 3] {
            q.push(req(id));
        }
        q.close();
        let b1 = q.pop_batch(8).unwrap();
        assert_eq!(b1.iter().map(|r| r.matrix_id).collect::<Vec<_>>(), [7; 3]);
        let b2 = q.pop_batch(8).unwrap();
        assert_eq!(b2.iter().map(|r| r.matrix_id).collect::<Vec<_>>(), [3; 2]);
        assert!(q.pop_batch(8).is_none());
    }

    #[test]
    fn pop_batch_respects_max() {
        let q = RequestQueue::new();
        for _ in 0..5 {
            q.push(req(1));
        }
        q.close();
        assert_eq!(q.pop_batch(2).unwrap().len(), 2);
        assert_eq!(q.pop_batch(2).unwrap().len(), 2);
        assert_eq!(q.pop_batch(2).unwrap().len(), 1);
        assert!(q.pop_batch(2).is_none());
    }

    #[test]
    fn close_unblocks_poppers() {
        let q = RequestQueue::new();
        std::thread::scope(|s| {
            let h = s.spawn(|| q.pop_batch(4));
            std::thread::sleep(std::time::Duration::from_millis(10));
            q.close();
            assert!(h.join().unwrap().is_none());
        });
    }
}
