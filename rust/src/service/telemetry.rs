//! Serving telemetry — batch/latency/cache accounting surfaced
//! through `util::table` and `util::json` so the replay harness, the
//! live worker pool, and the sharded server report the same schema.
//!
//! Latency percentiles are tracked two ways at once: an exact sample
//! reservoir capped at [`LATENCY_RESERVOIR_CAP`] entries, and a
//! constant-memory streaming digest (three P² estimators for
//! p50/p95/p99). Below the cap the report is exact; past it —
//! million-request replays — memory stays flat and the digest answers.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::json::Json;
use crate::util::stats::{self, P2Quantile};
use crate::util::table::Table;

/// Exact latency samples retained per stats object; the streaming
/// digest keeps percentiles accurate past this.
pub const LATENCY_RESERVOIR_CAP: usize = 65_536;

/// Constant-memory latency summary: count/mean/max exactly, and
/// p50/p95/p99 via streaming P² estimators.
#[derive(Clone, Debug)]
pub struct LatencyDigest {
    pub count: u64,
    pub sum_ms: f64,
    pub max_ms: f64,
    p50: P2Quantile,
    p95: P2Quantile,
    p99: P2Quantile,
}

impl Default for LatencyDigest {
    fn default() -> Self {
        LatencyDigest {
            count: 0,
            sum_ms: 0.0,
            max_ms: 0.0,
            p50: P2Quantile::new(0.50),
            p95: P2Quantile::new(0.95),
            p99: P2Quantile::new(0.99),
        }
    }
}

impl LatencyDigest {
    pub fn observe(&mut self, ms: f64) {
        self.count += 1;
        self.sum_ms += ms;
        self.max_ms = self.max_ms.max(ms);
        self.p50.observe(ms);
        self.p95.observe(ms);
        self.p99.observe(ms);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ms / self.count as f64
        }
    }

    /// Streaming estimate for the tracked percentiles (50/95/99);
    /// `None` for any other `p`.
    pub fn percentile(&self, p: f64) -> Option<f64> {
        for est in [&self.p50, &self.p95, &self.p99] {
            if (est.p() * 100.0 - p).abs() < 1e-9 {
                return Some(est.quantile());
            }
        }
        None
    }

    /// Fold another digest in. Count/mean/max merge exactly; the
    /// percentile estimators blend approximately (see
    /// [`P2Quantile::merge`]).
    pub fn merge(&mut self, other: &LatencyDigest) {
        self.count += other.count;
        self.sum_ms += other.sum_ms;
        self.max_ms = self.max_ms.max(other.max_ms);
        self.p50.merge(&other.p50);
        self.p95.merge(&other.p95);
        self.p99.merge(&other.p99);
    }
}

/// Aggregated serving counters (one snapshot == one report).
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    /// Batches of size 1 (fell back to single-vector SpMV).
    pub singletons: u64,
    /// Batch-size histogram: size -> count of batches.
    pub batch_hist: BTreeMap<usize, u64>,
    /// Requests per matrix id.
    pub per_matrix: BTreeMap<usize, u64>,
    /// Requests per *effective executed* schedule name. Batched
    /// dispatches against tile (CSR5) plans run the CsrRowBalanced
    /// remap — this map records what actually ran, so replay tables
    /// stop attributing SpMM throughput to CSR5.
    pub per_schedule: BTreeMap<String, u64>,
    /// Total measured kernel wall seconds.
    pub exec_seconds: f64,
    /// Total executed flops (2 * nnz * batch per dispatch).
    pub flops: f64,
    /// Per-request latencies in milliseconds (virtual in replay mode,
    /// wall-clock in the live worker-pool mode), capped at
    /// [`LATENCY_RESERVOIR_CAP`] samples — the digest carries the
    /// percentiles beyond that.
    pub latencies_ms: Vec<f64>,
    /// Streaming latency summary (exact count/mean/max, P² p50/95/99).
    pub digest: LatencyDigest,
    /// Streaming enqueue-to-dispatch wait summary, tracked separately
    /// from service time: requests are stamped at enqueue (live
    /// queues) or arrival (virtual replay timelines) and the wait is
    /// observed when their batch is popped for dispatch. End-to-end
    /// latency = queue wait + service; this digest makes the split
    /// visible.
    pub queue_wait: LatencyDigest,
    /// Requests refused at admission (bounded queue full / closed).
    pub rejected: u64,
    /// Requests dropped by deadline-based load shedding.
    pub shed: u64,
    /// Requests that reached execution and failed (unregistered
    /// matrix id, wrong vector length) — reported, never a panic.
    pub errors: u64,
}

impl ServeStats {
    /// Record one dispatched (possibly coalesced) batch. `schedule`
    /// is the *effective executed* schedule name (see
    /// [`crate::service::Plan::effective_schedule`]), not the plan's
    /// nominal one.
    pub fn record_batch(
        &mut self,
        matrix_id: usize,
        size: usize,
        wall_seconds: f64,
        flops: f64,
        schedule: &str,
    ) {
        self.requests += size as u64;
        self.batches += 1;
        if size == 1 {
            self.singletons += 1;
        }
        *self.batch_hist.entry(size).or_insert(0) += 1;
        *self.per_matrix.entry(matrix_id).or_insert(0) += size as u64;
        // Look up before inserting: `entry(schedule.to_string())`
        // would allocate the key String on *every* dispatch; the warm
        // serving path must only allocate on first sight of a name.
        match self.per_schedule.get_mut(schedule) {
            Some(count) => *count += size as u64,
            None => {
                self.per_schedule.insert(schedule.to_string(), size as u64);
            }
        }
        self.exec_seconds += wall_seconds;
        self.flops += flops;
    }

    pub fn record_latency_ms(&mut self, ms: f64) {
        self.digest.observe(ms);
        if self.latencies_ms.len() < LATENCY_RESERVOIR_CAP {
            self.latencies_ms.push(ms);
        }
    }

    /// Record one request's enqueue-to-dispatch wait.
    pub fn record_queue_wait_ms(&mut self, ms: f64) {
        self.queue_wait.observe(ms);
    }

    pub fn record_rejected(&mut self, n: u64) {
        self.rejected += n;
    }

    pub fn record_shed(&mut self, n: u64) {
        self.shed += n;
    }

    pub fn record_errors(&mut self, n: u64) {
        self.errors += n;
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    pub fn executed_gflops(&self) -> f64 {
        if self.exec_seconds > 0.0 {
            self.flops / self.exec_seconds / 1e9
        } else {
            0.0
        }
    }

    /// Mean latency — exact at any scale (tracked by the digest).
    pub fn latency_mean(&self) -> f64 {
        self.digest.mean()
    }

    /// Latency percentile: exact while the reservoir holds every
    /// sample, streaming (P², for p in {50, 95, 99}) once samples
    /// have been dropped.
    pub fn latency_percentile(&self, p: f64) -> f64 {
        if self.digest.count > self.latencies_ms.len() as u64 {
            if let Some(est) = self.digest.percentile(p) {
                return est;
            }
        }
        stats::percentile(&self.latencies_ms, p)
    }

    /// Fold another stats object in (per-shard -> fleet roll-up).
    /// Counters merge exactly; percentiles stay exact while the
    /// merged reservoir holds every sample.
    pub fn merge(&mut self, other: &ServeStats) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.singletons += other.singletons;
        for (&size, &count) in &other.batch_hist {
            *self.batch_hist.entry(size).or_insert(0) += count;
        }
        for (&id, &count) in &other.per_matrix {
            *self.per_matrix.entry(id).or_insert(0) += count;
        }
        for (name, &count) in &other.per_schedule {
            *self.per_schedule.entry(name.clone()).or_insert(0) += count;
        }
        self.exec_seconds += other.exec_seconds;
        self.flops += other.flops;
        for &ms in &other.latencies_ms {
            if self.latencies_ms.len() < LATENCY_RESERVOIR_CAP {
                self.latencies_ms.push(ms);
            }
        }
        self.digest.merge(&other.digest);
        self.queue_wait.merge(&other.queue_wait);
        self.rejected += other.rejected;
        self.shed += other.shed;
        self.errors += other.errors;
    }
}

/// Shared-mutable telemetry for concurrent recorders.
#[derive(Default)]
pub struct Telemetry {
    inner: Mutex<ServeStats>,
}

impl Telemetry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Lock the stats, recovering from poison — recorders only run
    /// short panic-free accounting sections, so the state is always
    /// consistent.
    fn state(&self) -> std::sync::MutexGuard<'_, ServeStats> {
        self.inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    pub fn record_batch(
        &self,
        matrix_id: usize,
        size: usize,
        wall_seconds: f64,
        flops: f64,
        schedule: &str,
    ) {
        self.state()
            .record_batch(matrix_id, size, wall_seconds, flops, schedule);
    }

    pub fn record_latency_ms(&self, ms: f64) {
        self.state().record_latency_ms(ms);
    }

    pub fn record_queue_wait_ms(&self, ms: f64) {
        self.state().record_queue_wait_ms(ms);
    }

    pub fn record_rejected(&self, n: u64) {
        self.state().record_rejected(n);
    }

    pub fn record_shed(&self, n: u64) {
        self.state().record_shed(n);
    }

    pub fn record_errors(&self, n: u64) {
        self.state().record_errors(n);
    }

    pub fn snapshot(&self) -> ServeStats {
        self.state().clone()
    }
}

/// One shard's slice of a serving run, for the per-shard report.
#[derive(Clone, Debug)]
pub struct ShardSnapshot {
    pub shard: usize,
    /// Modeled FT-2000+ panel core range `[c0, c1)` the shard's
    /// workers pin to.
    pub cores: (usize, usize),
    pub stats: ServeStats,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub duration_s: f64,
}

/// Per-shard stats table (shard = modeled NUMA panel).
pub fn shard_table(snaps: &[ShardSnapshot]) -> Table {
    let mut t = Table::new(
        "Per-shard serving stats (shard = modeled FT-2000+ panel)",
        &[
            "shard", "cores", "req", "rej", "shed", "err", "req/s",
            "p50 ms", "p95 ms", "p99 ms", "qw p50", "qw p95", "batch",
            "hit%",
        ],
    );
    for s in snaps {
        let thr = if s.duration_s > 0.0 {
            s.stats.requests as f64 / s.duration_s
        } else {
            0.0
        };
        let total = s.cache_hits + s.cache_misses;
        // A shard that saw no lookups has no hit rate — print n/a so a
        // cold (but addressed) cache and an idle shard stay
        // distinguishable.
        let hit = if total > 0 {
            format!("{:.1}", 100.0 * s.cache_hits as f64 / total as f64)
        } else {
            "n/a".to_string()
        };
        t.row(vec![
            s.shard.to_string(),
            format!("{}-{}", s.cores.0, s.cores.1.saturating_sub(1)),
            s.stats.requests.to_string(),
            s.stats.rejected.to_string(),
            s.stats.shed.to_string(),
            s.stats.errors.to_string(),
            format!("{thr:.0}"),
            format!("{:.3}", s.stats.latency_percentile(50.0)),
            format!("{:.3}", s.stats.latency_percentile(95.0)),
            format!("{:.3}", s.stats.latency_percentile(99.0)),
            format!(
                "{:.3}",
                s.stats.queue_wait.percentile(50.0).unwrap_or(0.0)
            ),
            format!(
                "{:.3}",
                s.stats.queue_wait.percentile(95.0).unwrap_or(0.0)
            ),
            format!("{:.2}", s.stats.mean_batch()),
            hit,
        ]);
    }
    t
}

/// Render a serving report table from a stats snapshot plus the
/// plan-cache accounting.
pub fn report_table(
    title: impl Into<String>,
    stats: &ServeStats,
    cache_hits: u64,
    cache_misses: u64,
    duration_s: f64,
) -> Table {
    let mut t = Table::new(title, &["metric", "value"]);
    let thr = if duration_s > 0.0 {
        stats.requests as f64 / duration_s
    } else {
        0.0
    };
    t.row(vec!["requests".into(), stats.requests.to_string()]);
    t.row(vec!["batches".into(), stats.batches.to_string()]);
    t.row(vec!["mean batch size".into(), format!("{:.2}", stats.mean_batch())]);
    t.row(vec![
        "singleton batches".into(),
        format!(
            "{} ({:.1}%)",
            stats.singletons,
            if stats.batches > 0 {
                100.0 * stats.singletons as f64 / stats.batches as f64
            } else {
                0.0
            }
        ),
    ]);
    if !stats.per_schedule.is_empty() {
        t.row(vec![
            "served by schedule (effective)".into(),
            stats
                .per_schedule
                .iter()
                .map(|(name, count)| format!("{name}: {count}"))
                .collect::<Vec<_>>()
                .join(", "),
        ]);
    }
    t.row(vec!["rejected (admission)".into(), stats.rejected.to_string()]);
    t.row(vec!["shed (deadline)".into(), stats.shed.to_string()]);
    t.row(vec!["exec errors".into(), stats.errors.to_string()]);
    t.row(vec!["duration".into(), format!("{duration_s:.4} s")]);
    t.row(vec!["throughput".into(), format!("{thr:.1} req/s")]);
    for (label, p) in [("p50", 50.0), ("p95", 95.0), ("p99", 99.0)] {
        t.row(vec![
            format!("latency {label}"),
            format!("{:.3} ms", stats.latency_percentile(p)),
        ]);
    }
    t.row(vec![
        "latency mean".into(),
        format!("{:.3} ms", stats.latency_mean()),
    ]);
    for (label, p) in [("p50", 50.0), ("p95", 95.0)] {
        t.row(vec![
            format!("queue wait {label}"),
            format!(
                "{:.3} ms",
                stats.queue_wait.percentile(p).unwrap_or(0.0)
            ),
        ]);
    }
    let total = cache_hits + cache_misses;
    t.row(vec![
        "plan-cache hit rate".into(),
        // No lookups yet: there is no rate. `n/a` keeps an idle cache
        // distinguishable from a genuinely cold one at 0%.
        if total > 0 {
            format!(
                "{:.1}% ({cache_hits}/{total})",
                100.0 * cache_hits as f64 / total as f64
            )
        } else {
            "n/a (0/0)".to_string()
        },
    ]);
    t.row(vec![
        "executed".into(),
        format!(
            "{:.3} Gflop in {:.4} s kernel time ({:.3} Gflops)",
            stats.flops / 1e9,
            stats.exec_seconds,
            stats.executed_gflops()
        ),
    ]);
    t
}

/// Batch-size histogram as its own table (the report's second block).
pub fn batch_histogram_table(stats: &ServeStats) -> Table {
    let mut t =
        Table::new("Batch-size histogram", &["batch size", "batches", "share"]);
    // `batches` is normally the histogram total; guard the division
    // so a hand-built or empty snapshot prints 0%, never NaN%.
    let denom = stats.batches.max(1) as f64;
    for (&size, &count) in &stats.batch_hist {
        t.row(vec![
            size.to_string(),
            count.to_string(),
            format!("{:.1}%", 100.0 * count as f64 / denom),
        ]);
    }
    t
}

/// JSON form of the serving report (machine-readable campaign files).
pub fn report_json(
    stats: &ServeStats,
    cache_hits: u64,
    cache_misses: u64,
    duration_s: f64,
) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("requests".into(), Json::Num(stats.requests as f64));
    obj.insert("batches".into(), Json::Num(stats.batches as f64));
    obj.insert("mean_batch".into(), Json::Num(stats.mean_batch()));
    obj.insert("rejected".into(), Json::Num(stats.rejected as f64));
    obj.insert("shed".into(), Json::Num(stats.shed as f64));
    obj.insert("errors".into(), Json::Num(stats.errors as f64));
    obj.insert("duration_s".into(), Json::Num(duration_s));
    obj.insert(
        "throughput_rps".into(),
        Json::Num(if duration_s > 0.0 {
            stats.requests as f64 / duration_s
        } else {
            0.0
        }),
    );
    obj.insert(
        "latency_ms".into(),
        Json::Obj(
            [
                ("p50".to_string(), Json::Num(stats.latency_percentile(50.0))),
                ("p95".to_string(), Json::Num(stats.latency_percentile(95.0))),
                ("p99".to_string(), Json::Num(stats.latency_percentile(99.0))),
                ("mean".to_string(), Json::Num(stats.latency_mean())),
            ]
            .into_iter()
            .collect(),
        ),
    );
    obj.insert(
        "queue_wait_ms".into(),
        Json::Obj(
            [
                (
                    "p50".to_string(),
                    Json::Num(
                        stats.queue_wait.percentile(50.0).unwrap_or(0.0),
                    ),
                ),
                (
                    "p95".to_string(),
                    Json::Num(
                        stats.queue_wait.percentile(95.0).unwrap_or(0.0),
                    ),
                ),
                ("mean".to_string(), Json::Num(stats.queue_wait.mean())),
                (
                    "count".to_string(),
                    Json::Num(stats.queue_wait.count as f64),
                ),
            ]
            .into_iter()
            .collect(),
        ),
    );
    obj.insert("cache_hits".into(), Json::Num(cache_hits as f64));
    obj.insert("cache_misses".into(), Json::Num(cache_misses as f64));
    obj.insert(
        "batch_hist".into(),
        Json::Arr(
            stats
                .batch_hist
                .iter()
                .map(|(&s, &c)| {
                    Json::Arr(vec![Json::Num(s as f64), Json::Num(c as f64)])
                })
                .collect(),
        ),
    );
    obj.insert(
        "per_schedule".into(),
        Json::Obj(
            stats
                .per_schedule
                .iter()
                .map(|(name, &count)| (name.clone(), Json::Num(count as f64)))
                .collect(),
        ),
    );
    obj.insert("executed_gflops".into(), Json::Num(stats.executed_gflops()));
    Json::Obj(obj)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_accumulate() {
        let t = Telemetry::new();
        t.record_batch(0, 4, 0.5, 8e9, "csr-balanced");
        t.record_batch(0, 1, 0.5, 1e9, "csr5-t256");
        t.record_batch(3, 4, 0.0, 0.0, "csr-balanced");
        t.record_latency_ms(1.0);
        t.record_latency_ms(3.0);
        t.record_rejected(2);
        t.record_shed(1);
        t.record_errors(4);
        let s = t.snapshot();
        assert_eq!(s.requests, 9);
        assert_eq!(s.batches, 3);
        assert_eq!(s.singletons, 1);
        assert_eq!(s.batch_hist.get(&4), Some(&2));
        assert_eq!(s.per_matrix.get(&0), Some(&5));
        assert_eq!(s.per_schedule.get("csr-balanced"), Some(&8));
        assert_eq!(s.per_schedule.get("csr5-t256"), Some(&1));
        assert!((s.mean_batch() - 3.0).abs() < 1e-12);
        assert!((s.executed_gflops() - 9.0).abs() < 1e-12);
        assert_eq!(s.latency_percentile(100.0), 3.0);
        assert_eq!((s.rejected, s.shed, s.errors), (2, 1, 4));
        assert_eq!(s.digest.count, 2);
        assert!((s.latency_mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn report_renders() {
        let mut s = ServeStats::default();
        s.record_batch(0, 2, 0.001, 1e6, "csr-static");
        s.record_latency_ms(0.5);
        s.record_latency_ms(1.5);
        s.record_errors(1);
        let md = report_table("Serving report", &s, 3, 1, 2.0).to_markdown();
        assert!(md.contains("plan-cache hit rate"));
        assert!(md.contains("75.0%"));
        assert!(md.contains("latency p99"));
        assert!(md.contains("exec errors"));
        assert!(md.contains("csr-static: 2"), "effective schedule row: {md}");
        let j = report_json(&s, 3, 1, 2.0);
        assert_eq!(j.get("cache_hits").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("errors").unwrap().as_f64(), Some(1.0));
        assert!(j.get("latency_ms").unwrap().get("p50").is_some());
        assert_eq!(
            j.get("per_schedule").unwrap().get("csr-static").unwrap().as_f64(),
            Some(2.0)
        );
        assert!(!batch_histogram_table(&s).is_empty());
    }

    #[test]
    fn empty_histogram_has_no_nan() {
        // A snapshot with histogram entries but batches forced to 0
        // (hand-built) must not print NaN%.
        let mut s = ServeStats::default();
        s.batch_hist.insert(4, 2);
        let md = batch_histogram_table(&s).to_markdown();
        assert!(!md.contains("NaN"), "histogram rendered NaN: {md}");
        let empty = ServeStats::default();
        let md = report_table("r", &empty, 0, 0, 0.0).to_markdown();
        assert!(!md.contains("NaN"), "empty report rendered NaN: {md}");
        assert!(
            md.contains("n/a (0/0)"),
            "zero-lookup cache must render n/a, not 0%: {md}"
        );
    }

    #[test]
    fn idle_shard_hit_rate_is_na() {
        let snap = ShardSnapshot {
            shard: 0,
            cores: (0, 8),
            stats: ServeStats::default(),
            cache_hits: 0,
            cache_misses: 0,
            duration_s: 1.0,
        };
        let md = shard_table(&[snap]).to_markdown();
        assert!(md.contains("n/a"), "idle shard must render n/a: {md}");
    }

    #[test]
    fn reservoir_caps_but_digest_keeps_counting() {
        let mut s = ServeStats::default();
        let n = LATENCY_RESERVOIR_CAP + 10_000;
        let mut state = 0x1234_5678_u64;
        for _ in 0..n {
            // xorshift latencies in (0, 10).
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            let ms = (state % 10_000) as f64 / 1000.0;
            s.record_latency_ms(ms);
        }
        assert_eq!(s.latencies_ms.len(), LATENCY_RESERVOIR_CAP);
        assert_eq!(s.digest.count, n as u64);
        // Percentiles answer from the streaming digest, near-uniform.
        let p50 = s.latency_percentile(50.0);
        let p99 = s.latency_percentile(99.0);
        assert!((p50 - 5.0).abs() < 0.5, "p50 {p50}");
        assert!(p99 > 9.0 && p99 <= 10.0, "p99 {p99}");
        assert!(s.latency_mean() > 0.0);
    }

    #[test]
    fn queue_wait_is_tracked_separately_from_service() {
        let t = Telemetry::new();
        for i in 0..20 {
            t.record_queue_wait_ms(0.1 * (i + 1) as f64);
            t.record_latency_ms(5.0);
        }
        let s = t.snapshot();
        assert_eq!(s.queue_wait.count, 20);
        assert_eq!(s.digest.count, 20);
        let p50 = s.queue_wait.percentile(50.0).unwrap();
        let p95 = s.queue_wait.percentile(95.0).unwrap();
        assert!((0.5..=1.6).contains(&p50), "queue-wait p50 {p50}");
        assert!(p95 >= p50, "p95 {p95} < p50 {p50}");
        assert!((s.queue_wait.mean() - 1.05).abs() < 1e-9);
        // Waits never leak into the service-latency digest.
        assert_eq!(s.latency_percentile(50.0), 5.0);
        // Surfaces: report rows + JSON block + shard columns.
        let md = report_table("r", &s, 0, 0, 1.0).to_markdown();
        assert!(md.contains("queue wait p50"), "{md}");
        assert!(md.contains("queue wait p95"), "{md}");
        let j = report_json(&s, 0, 0, 1.0);
        let qw = j.get("queue_wait_ms").expect("queue_wait_ms block");
        assert_eq!(qw.get("count").unwrap().as_usize(), Some(20));
        assert!(qw.get("p50").unwrap().as_f64().unwrap() > 0.0);
        let snap = ShardSnapshot {
            shard: 0,
            cores: (0, 8),
            stats: s,
            cache_hits: 0,
            cache_misses: 0,
            duration_s: 1.0,
        };
        let md = shard_table(&[snap]).to_markdown();
        assert!(md.contains("qw p50"), "{md}");
        // Merge folds the wait digests too.
        let mut a = ServeStats::default();
        a.record_queue_wait_ms(1.0);
        let mut b = ServeStats::default();
        b.record_queue_wait_ms(3.0);
        a.merge(&b);
        assert_eq!(a.queue_wait.count, 2);
        assert!((a.queue_wait.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn merge_rolls_up_shards() {
        let mut a = ServeStats::default();
        a.record_batch(0, 2, 0.1, 1e9, "csr-static");
        a.record_latency_ms(1.0);
        a.record_rejected(1);
        let mut b = ServeStats::default();
        b.record_batch(1, 3, 0.1, 2e9, "csr-balanced");
        b.record_latency_ms(2.0);
        b.record_latency_ms(4.0);
        b.record_errors(2);
        a.merge(&b);
        assert_eq!(a.requests, 5);
        assert_eq!(a.batches, 2);
        assert_eq!(a.rejected, 1);
        assert_eq!(a.errors, 2);
        assert_eq!(a.digest.count, 3);
        assert_eq!(a.latencies_ms.len(), 3);
        assert!((a.latency_mean() - 7.0 / 3.0).abs() < 1e-12);
        assert_eq!(a.per_matrix.get(&1), Some(&3));
        assert_eq!(a.per_schedule.get("csr-static"), Some(&2));
        assert_eq!(a.per_schedule.get("csr-balanced"), Some(&3));
        assert_eq!(a.latency_percentile(100.0), 4.0);
    }

    #[test]
    fn shard_table_renders() {
        let mut s = ServeStats::default();
        s.record_batch(0, 2, 0.01, 1e6, "csr-static");
        s.record_latency_ms(1.0);
        s.record_latency_ms(2.0);
        let snap = ShardSnapshot {
            shard: 3,
            cores: (24, 32),
            stats: s,
            cache_hits: 1,
            cache_misses: 1,
            duration_s: 0.5,
        };
        let md = shard_table(&[snap]).to_markdown();
        assert!(md.contains("24-31"));
        assert!(md.contains("50.0"));
        assert!(!md.contains("NaN"));
    }
}
