//! Serving telemetry — batch/latency/cache accounting surfaced
//! through `util::table` and `util::json` so the replay harness and
//! the live worker-pool bench report the same schema.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::json::Json;
use crate::util::stats;
use crate::util::table::Table;

/// Aggregated serving counters (one snapshot == one report).
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    /// Batches of size 1 (fell back to single-vector SpMV).
    pub singletons: u64,
    /// Batch-size histogram: size -> count of batches.
    pub batch_hist: BTreeMap<usize, u64>,
    /// Requests per matrix id.
    pub per_matrix: BTreeMap<usize, u64>,
    /// Total measured kernel wall seconds.
    pub exec_seconds: f64,
    /// Total executed flops (2 * nnz * batch per dispatch).
    pub flops: f64,
    /// Per-request latencies in milliseconds (virtual in replay mode,
    /// wall-clock in the live worker-pool mode).
    pub latencies_ms: Vec<f64>,
}

impl ServeStats {
    pub fn record_batch(
        &mut self,
        matrix_id: usize,
        size: usize,
        wall_seconds: f64,
        flops: f64,
    ) {
        self.requests += size as u64;
        self.batches += 1;
        if size == 1 {
            self.singletons += 1;
        }
        *self.batch_hist.entry(size).or_insert(0) += 1;
        *self.per_matrix.entry(matrix_id).or_insert(0) += size as u64;
        self.exec_seconds += wall_seconds;
        self.flops += flops;
    }

    pub fn record_latency_ms(&mut self, ms: f64) {
        self.latencies_ms.push(ms);
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    pub fn executed_gflops(&self) -> f64 {
        if self.exec_seconds > 0.0 {
            self.flops / self.exec_seconds / 1e9
        } else {
            0.0
        }
    }

    pub fn latency_percentile(&self, p: f64) -> f64 {
        stats::percentile(&self.latencies_ms, p)
    }
}

/// Shared-mutable telemetry for concurrent recorders.
#[derive(Default)]
pub struct Telemetry {
    inner: Mutex<ServeStats>,
}

impl Telemetry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(
        &self,
        matrix_id: usize,
        size: usize,
        wall_seconds: f64,
        flops: f64,
    ) {
        self.inner
            .lock()
            .unwrap()
            .record_batch(matrix_id, size, wall_seconds, flops);
    }

    pub fn record_latency_ms(&self, ms: f64) {
        self.inner.lock().unwrap().record_latency_ms(ms);
    }

    pub fn snapshot(&self) -> ServeStats {
        self.inner.lock().unwrap().clone()
    }
}

/// Render a serving report table from a stats snapshot plus the
/// plan-cache accounting.
pub fn report_table(
    title: impl Into<String>,
    stats: &ServeStats,
    cache_hits: u64,
    cache_misses: u64,
    duration_s: f64,
) -> Table {
    let mut t = Table::new(title, &["metric", "value"]);
    let thr = if duration_s > 0.0 {
        stats.requests as f64 / duration_s
    } else {
        0.0
    };
    t.row(vec!["requests".into(), stats.requests.to_string()]);
    t.row(vec!["batches".into(), stats.batches.to_string()]);
    t.row(vec!["mean batch size".into(), format!("{:.2}", stats.mean_batch())]);
    t.row(vec![
        "singleton batches".into(),
        format!(
            "{} ({:.1}%)",
            stats.singletons,
            if stats.batches > 0 {
                100.0 * stats.singletons as f64 / stats.batches as f64
            } else {
                0.0
            }
        ),
    ]);
    t.row(vec!["duration".into(), format!("{duration_s:.4} s")]);
    t.row(vec!["throughput".into(), format!("{thr:.1} req/s")]);
    for (label, p) in [("p50", 50.0), ("p95", 95.0), ("p99", 99.0)] {
        t.row(vec![
            format!("latency {label}"),
            format!("{:.3} ms", stats.latency_percentile(p)),
        ]);
    }
    t.row(vec![
        "latency mean".into(),
        format!("{:.3} ms", stats::mean(&stats.latencies_ms)),
    ]);
    let total = cache_hits + cache_misses;
    t.row(vec![
        "plan-cache hit rate".into(),
        format!(
            "{:.1}% ({cache_hits}/{total})",
            if total > 0 {
                100.0 * cache_hits as f64 / total as f64
            } else {
                0.0
            }
        ),
    ]);
    t.row(vec![
        "executed".into(),
        format!(
            "{:.3} Gflop in {:.4} s kernel time ({:.3} Gflops)",
            stats.flops / 1e9,
            stats.exec_seconds,
            stats.executed_gflops()
        ),
    ]);
    t
}

/// Batch-size histogram as its own table (the report's second block).
pub fn batch_histogram_table(stats: &ServeStats) -> Table {
    let mut t =
        Table::new("Batch-size histogram", &["batch size", "batches", "share"]);
    for (&size, &count) in &stats.batch_hist {
        t.row(vec![
            size.to_string(),
            count.to_string(),
            format!("{:.1}%", 100.0 * count as f64 / stats.batches as f64),
        ]);
    }
    t
}

/// JSON form of the serving report (machine-readable campaign files).
pub fn report_json(
    stats: &ServeStats,
    cache_hits: u64,
    cache_misses: u64,
    duration_s: f64,
) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("requests".into(), Json::Num(stats.requests as f64));
    obj.insert("batches".into(), Json::Num(stats.batches as f64));
    obj.insert("mean_batch".into(), Json::Num(stats.mean_batch()));
    obj.insert("duration_s".into(), Json::Num(duration_s));
    obj.insert(
        "throughput_rps".into(),
        Json::Num(if duration_s > 0.0 {
            stats.requests as f64 / duration_s
        } else {
            0.0
        }),
    );
    obj.insert(
        "latency_ms".into(),
        Json::Obj(
            [
                ("p50".to_string(), Json::Num(stats.latency_percentile(50.0))),
                ("p95".to_string(), Json::Num(stats.latency_percentile(95.0))),
                ("p99".to_string(), Json::Num(stats.latency_percentile(99.0))),
                ("mean".to_string(), Json::Num(stats::mean(&stats.latencies_ms))),
            ]
            .into_iter()
            .collect(),
        ),
    );
    obj.insert("cache_hits".into(), Json::Num(cache_hits as f64));
    obj.insert("cache_misses".into(), Json::Num(cache_misses as f64));
    obj.insert(
        "batch_hist".into(),
        Json::Arr(
            stats
                .batch_hist
                .iter()
                .map(|(&s, &c)| {
                    Json::Arr(vec![Json::Num(s as f64), Json::Num(c as f64)])
                })
                .collect(),
        ),
    );
    obj.insert("executed_gflops".into(), Json::Num(stats.executed_gflops()));
    Json::Obj(obj)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_accumulate() {
        let t = Telemetry::new();
        t.record_batch(0, 4, 0.5, 8e9);
        t.record_batch(0, 1, 0.5, 1e9);
        t.record_batch(3, 4, 0.0, 0.0);
        t.record_latency_ms(1.0);
        t.record_latency_ms(3.0);
        let s = t.snapshot();
        assert_eq!(s.requests, 9);
        assert_eq!(s.batches, 3);
        assert_eq!(s.singletons, 1);
        assert_eq!(s.batch_hist.get(&4), Some(&2));
        assert_eq!(s.per_matrix.get(&0), Some(&5));
        assert!((s.mean_batch() - 3.0).abs() < 1e-12);
        assert!((s.executed_gflops() - 9.0).abs() < 1e-12);
        assert_eq!(s.latency_percentile(100.0), 3.0);
    }

    #[test]
    fn report_renders() {
        let mut s = ServeStats::default();
        s.record_batch(0, 2, 0.001, 1e6);
        s.record_latency_ms(0.5);
        s.record_latency_ms(1.5);
        let md = report_table("Serving report", &s, 3, 1, 2.0).to_markdown();
        assert!(md.contains("plan-cache hit rate"));
        assert!(md.contains("75.0%"));
        assert!(md.contains("latency p99"));
        let j = report_json(&s, 3, 1, 2.0);
        assert_eq!(j.get("cache_hits").unwrap().as_f64(), Some(3.0));
        assert!(j.get("latency_ms").unwrap().get("p50").is_some());
        assert!(!batch_histogram_table(&s).is_empty());
    }
}
