//! Batched SpMV serving subsystem — the request path of the engine.
//!
//! The paper's conclusion (and SpChar's after it) is that the right
//! format/schedule/thread placement for SpMV is a *per-matrix*
//! decision. A characterization harness makes that decision once per
//! experiment; a serving system must make it once per *matrix* and
//! then sustain heavy request traffic against it. This module adds
//! that layer:
//!
//! * [`registry`] — content-fingerprinted store of loaded matrices
//!   with precomputed features (load once, serve forever);
//! * [`plan`] — per-fingerprint memoized execution plans: schedule
//!   choice (heuristic thresholds or the learned
//!   `coordinator::format_select` tree), thread count/placement, and
//!   the pre-converted CSR5 structure when tiles win — with hit/miss
//!   accounting;
//! * [`batch`] — per-matrix-indexed request queue (optionally
//!   bounded) + worker pool that coalesces concurrent `y = A x`
//!   requests against the same matrix into one multi-vector
//!   `exec::spmm_threaded` launch (single-vector `spmv_threaded` for
//!   singletons); bad requests are error outcomes, not panics;
//! * [`shard`] — the panel-aware sharded server: per-shard queues,
//!   plan-cache views and telemetry, popularity/size placement with
//!   hot-matrix replication, bounded-queue admission control and
//!   deadline shedding (the paper's NUMA-panel topology, Fig 3,
//!   applied to serving);
//! * [`workload`] — deterministic open-loop (Poisson, bursty) and
//!   closed-loop traffic generators with uniform or Zipf matrix
//!   popularity;
//! * [`replay`] — virtual-time replay of a workload through the
//!   engine: deterministic latency percentiles from an explicit cost
//!   model, real kernel executions for measured throughput;
//! * [`telemetry`] — the serving report (throughput, p50/p95/p99,
//!   batch histogram, plan-cache hit rate) in table and JSON form.

pub mod batch;
pub mod plan;
pub mod registry;
pub mod replay;
pub mod shard;
pub mod telemetry;
pub mod workload;

pub use batch::{serve_queue, PushError, Request, RequestQueue};
pub use plan::{
    build_plan, build_plan_shared, build_plan_with, Plan, PlanCache,
    PlanConfig, PlannedFormat, Planner, SharedFormats,
};
pub use registry::{fingerprint, MatrixEntry, MatrixRegistry};
pub use replay::{
    replay, replay_sharded, CostModel, ReplayConfig, ReplayReport,
    ShardedReplayReport,
};
pub use shard::{
    Admitted, PlacementPolicy, Shard, ShardConfig, ShardPlacement,
    ShardedServer,
};
pub use telemetry::{ServeStats, ShardSnapshot, Telemetry};
pub use workload::{Arrivals, GenRequest, Popularity, WorkloadSpec};

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use crate::autotune::{AutotuneConfig, Autotuner, StageObs};
use crate::exec::{ExecPool, Scratch};
use crate::obs::scaling::{
    GapComponents, QueueWaitSummary, ScalingProfiler, MAX_LANES,
};
use crate::obs::{Counter, Histogram, MetricsRegistry, Stage, TraceRecorder};
use crate::resil::health::{DegradedMode, HealthTracker};
use crate::sched::Schedule;
use crate::util::json::Json;

/// Outcome of one (possibly coalesced) execution, with materialized
/// outputs — the compatibility path for callers that consume the
/// result vectors. The serving drain loops use
/// [`ServeEngine::serve_batch`] instead, which leaves outputs in the
/// engine's scratch arena and allocates nothing per request.
pub struct BatchOutcome {
    /// One output vector per request, in request order.
    pub ys: Vec<Vec<f64>>,
    pub wall_seconds: f64,
    pub plan_hit: bool,
    /// The *effective executed* schedule: batched dispatches against
    /// packed-format (CSR5/SELL) plans report the `CsrRowBalanced`
    /// remap they actually ran, not the plan's nominal schedule.
    pub schedule: Schedule,
    pub threads: usize,
    /// When the engine autotunes: the tuner arm this dispatch ran, to
    /// feed back to [`Autotuner::observe`] from an external clock
    /// (the virtual-time replay).
    pub arm: Option<usize>,
}

/// Metadata of one served dispatch whose outputs were written into
/// (and left in) the engine's scratch arena — everything the serving
/// loops and the replay cost model need, with zero per-request heap
/// allocation on the warm path (`tests/alloc.rs` pins this).
#[derive(Clone, Copy, Debug)]
pub struct BatchStats {
    pub wall_seconds: f64,
    pub plan_hit: bool,
    /// Effective executed schedule (see [`BatchOutcome::schedule`]).
    pub schedule: Schedule,
    pub threads: usize,
    /// Tuner arm of this dispatch (autotuned engines only).
    pub arm: Option<usize>,
}

/// The serving engine: registry + plan cache + telemetry + (when
/// serving) a persistent executor pool. Shared by reference across
/// worker threads (all interior state is locked). The registry is
/// behind an `Arc` so a sharded deployment can give every shard its
/// own engine view (private plan cache + telemetry) over one loaded
/// matrix store.
///
/// A pooled engine executes every request on its resident
/// [`ExecPool`] workers — the hot path pays no per-request thread
/// spawn and no re-partitioning (plans memoize their partition). The
/// spawn-mode constructors keep the scoped-thread fallback for
/// one-shot CLI paths and as the A/B baseline.
pub struct ServeEngine {
    pub registry: Arc<MatrixRegistry>,
    pub plans: PlanCache,
    pub telemetry: Telemetry,
    pool: Option<ExecPool>,
    tuner: Option<Autotuner>,
    /// Checked-out-per-dispatch scratch arenas (output, packed-x, and
    /// carry buffers). The pool grows to the engine's peak dispatch
    /// concurrency and each arena's buffers grow to the corpus's
    /// largest request — after that, serving allocates nothing.
    scratch: Mutex<Vec<Scratch>>,
    /// Optional stage-span recorder ([`ServeEngine::with_trace`]).
    trace: Option<Arc<TraceRecorder>>,
    /// The unified metrics registry behind
    /// [`ServeEngine::metrics_snapshot`].
    metrics: MetricsRegistry,
    /// Pre-registered hot-path instrument handles (atomic updates
    /// only — no name lookup, no lock, no allocation per dispatch).
    obs: EngineObs,
    /// Always-on scalability attribution: every dispatch's gap to
    /// linear speedup, decomposed and aggregated per fingerprint
    /// ([`ServeEngine::scaling_snapshot`]).
    scaling: ScalingProfiler,
    /// Fault/recovery ledger and degraded-mode ladder
    /// (`resil::health`): every dispatch consults the current rung,
    /// lane busy deltas feed the slow-lane detector, and autotune
    /// observations are suppressed while degraded.
    health: HealthTracker,
}

/// The engine's pre-registered instrument handles.
struct EngineObs {
    /// Dispatches served (batches, not requests).
    dispatches: Arc<Counter>,
    /// Per-request latency share of each dispatch.
    latency_ms: Arc<Histogram>,
    /// Cumulative µs spent per stage, indexed by [`Stage::index`]
    /// (only the engine-measured stages accumulate here).
    stage_us: Vec<Arc<Counter>>,
}

impl EngineObs {
    fn new(metrics: &MetricsRegistry) -> EngineObs {
        EngineObs {
            dispatches: metrics.counter("serve.dispatches"),
            latency_ms: metrics.histogram("serve.per_request_ms"),
            stage_us: Stage::all()
                .iter()
                .map(|s| {
                    metrics.counter(&format!("serve.stage.{}.us", s.name()))
                })
                .collect(),
        }
    }
}

impl ServeEngine {
    /// Spawn-mode engine (scoped threads per request) — the one-shot
    /// fallback and A/B baseline. Serving deployments should prefer
    /// [`ServeEngine::pooled`].
    pub fn new(
        registry: MatrixRegistry,
        planner: Planner,
        cfg: PlanConfig,
    ) -> Self {
        Self::shared(Arc::new(registry), planner, cfg)
    }

    /// Spawn-mode engine view over an already-shared registry.
    pub fn shared(
        registry: Arc<MatrixRegistry>,
        planner: Planner,
        cfg: PlanConfig,
    ) -> Self {
        let metrics = MetricsRegistry::new();
        let obs = EngineObs::new(&metrics);
        ServeEngine {
            registry,
            plans: PlanCache::new(planner, cfg),
            telemetry: Telemetry::new(),
            pool: None,
            tuner: None,
            scratch: Mutex::new(Vec::new()),
            trace: None,
            metrics,
            obs,
            scaling: ScalingProfiler::new(),
            health: HealthTracker::new(),
        }
    }

    /// Engine with a persistent executor pool sized to the plan
    /// thread count — requests reuse the resident workers.
    ///
    /// Trade-off: the pool serializes dispatches, so a *global*
    /// pooled engine shared by several queue workers runs one kernel
    /// at a time (plan-width wide). That wins whenever dispatch
    /// overhead dominates — the small/medium-matrix traffic a serving
    /// engine mostly sees — but for compute-heavy corpora on wide
    /// hosts the sharded deployment is the right shape: one pinned
    /// pool per shard keeps kernels concurrent across panels
    /// ([`ShardedServer`], the `serve-bench` default).
    pub fn pooled(
        registry: MatrixRegistry,
        planner: Planner,
        cfg: PlanConfig,
    ) -> Self {
        Self::shared_pooled(Arc::new(registry), planner, cfg)
    }

    /// Pooled engine view over an already-shared registry (see
    /// [`ServeEngine::pooled`] for the serialization trade-off).
    pub fn shared_pooled(
        registry: Arc<MatrixRegistry>,
        planner: Planner,
        cfg: PlanConfig,
    ) -> Self {
        let pool = ExecPool::new(cfg.n_threads.max(1));
        let mut engine = Self::shared(registry, planner, cfg);
        engine.pool = Some(pool);
        engine
    }

    /// Pooled engine view whose workers are (modeled) pinned to the
    /// core range `[c0, c1)` — one worker per core. The per-shard
    /// constructor: `service::shard` hands each shard its
    /// `sched::panel_core_range` block.
    ///
    /// Plans built by a pinned engine partition one slot per panel
    /// core (`n_threads` is widened to the core-range size), so a
    /// single dispatch saturates the panel's resident workers —
    /// without this, pool-serialized 4-wide kernels would leave half
    /// an 8-core panel parked and lose to the spawn baseline's
    /// oversubscription.
    pub fn shared_pinned(
        registry: Arc<MatrixRegistry>,
        planner: Planner,
        mut cfg: PlanConfig,
        cores: (usize, usize),
    ) -> Self {
        cfg.n_threads = cores.1.saturating_sub(cores.0).max(1);
        let pool = ExecPool::pinned(cores);
        let mut engine = Self::shared(registry, planner, cfg);
        engine.pool = Some(pool);
        engine
    }

    /// Engine in the given dispatch mode — the CLI's `--pool` /
    /// `--spawn` toggle in constructor form.
    pub fn with_mode(
        pooled: bool,
        registry: MatrixRegistry,
        planner: Planner,
        cfg: PlanConfig,
    ) -> Self {
        Self::shared_with_mode(pooled, Arc::new(registry), planner, cfg)
    }

    /// [`ServeEngine::with_mode`] over an already-shared registry.
    pub fn shared_with_mode(
        pooled: bool,
        registry: Arc<MatrixRegistry>,
        planner: Planner,
        cfg: PlanConfig,
    ) -> Self {
        if pooled {
            Self::shared_pooled(registry, planner, cfg)
        } else {
            Self::shared(registry, planner, cfg)
        }
    }

    /// The engine's resident executor pool, if it serves pooled.
    pub fn pool(&self) -> Option<&ExecPool> {
        self.pool.as_ref()
    }

    pub fn is_pooled(&self) -> bool {
        self.pool.is_some()
    }

    /// Enable online plan autotuning: every dispatch becomes an
    /// explore/exploit pull over plan variants, and measured latency
    /// feeds promotions back into the plan cache. The tuner's variant
    /// plans are built from this engine's own [`PlanConfig`], so a
    /// panel-pinned engine tunes within its panel width.
    pub fn with_tuner(mut self, cfg: AutotuneConfig) -> Self {
        let plan_cfg = self.plans.config().clone();
        self.tuner = Some(Autotuner::new(cfg, plan_cfg));
        self
    }

    /// Attach an already-constructed (e.g. JSON-warm-started) tuner.
    pub fn with_tuner_state(mut self, tuner: Autotuner) -> Self {
        self.tuner = Some(tuner);
        self
    }

    pub fn tuner(&self) -> Option<&Autotuner> {
        self.tuner.as_ref()
    }

    pub fn is_tuned(&self) -> bool {
        self.tuner.is_some()
    }

    /// Attach a stage-span recorder: dispatches emit plan-lookup /
    /// partition / kernel / reduce / autotune-observe spans, and a
    /// pooled engine's workers emit per-lane kernel spans. Without a
    /// recorder the dispatch path pays one `Option` branch.
    pub fn with_trace(mut self, rec: Arc<TraceRecorder>) -> Self {
        if let Some(pool) = &self.pool {
            pool.set_trace(rec.clone());
        }
        self.trace = Some(rec);
        self
    }

    /// The attached span recorder, if tracing is on.
    pub fn trace(&self) -> Option<&Arc<TraceRecorder>> {
        self.trace.as_ref()
    }

    /// The engine's unified metrics registry (see
    /// [`ServeEngine::metrics_snapshot`] for the one-call export).
    pub fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    /// The always-on scalability profiler (see
    /// [`ServeEngine::scaling_snapshot`] for the one-call export).
    pub fn scaling(&self) -> &ScalingProfiler {
        &self.scaling
    }

    /// Disable scalability attribution — the A/B baseline for the
    /// `obs` bench section's profiler-tax gate. Serving deployments
    /// leave it on (the default).
    pub fn without_scaling(mut self) -> Self {
        self.scaling.set_enabled(false);
        self
    }

    /// The engine's fault/recovery ledger (`resil::health`): the
    /// degraded-mode ladder the dispatch path consults, plus every
    /// counted graceful outcome. Chaos drivers and shard routers
    /// escalate/recover through this handle; fleets roll engines up
    /// with [`HealthTracker::merge_from`].
    pub fn health(&self) -> &HealthTracker {
        &self.health
    }

    /// The versioned `ft2000.health.v1` snapshot of this engine's
    /// health ledger.
    pub fn health_snapshot(&self) -> Json {
        self.health.snapshot()
    }

    /// Resolve the plan one dispatch against `entry` should run —
    /// shared by the live path ([`ServeEngine::execute_batch`]) and
    /// the virtual-time replay's model-only dispatcher so both obey
    /// the same rules. Returns `(plan, cache hit, tuner arm)`:
    ///
    /// * the cache lookup consults the tuner's promoted winner first,
    ///   so an LRU-evicted promotion is re-installed directly
    ///   ([`PlanCache::hit_or_install`]) instead of rebuilding (and
    ///   then discarding) the static plan;
    /// * on a tuned engine the returned plan is the tuner's
    ///   explore/exploit pick; the cached plan stays the baseline arm
    ///   every promotion is judged against.
    pub(crate) fn plan_for_dispatch(
        &self,
        entry: &MatrixEntry,
    ) -> (Arc<Plan>, bool, Option<usize>) {
        let winner = self
            .tuner
            .as_ref()
            .and_then(|t| t.chosen_plan(entry.fingerprint));
        let (plan, plan_hit) = match winner {
            Some(w) => self.plans.hit_or_install(entry.fingerprint, w),
            None => self.plans.plan_for(entry.fingerprint, &entry.csr),
        };
        let (plan, arm) = match &self.tuner {
            Some(t) => {
                let (p, a) = t.plan_for(
                    entry.fingerprint,
                    &entry.name,
                    &plan,
                    &entry.csr,
                );
                (p, Some(a))
            }
            None => (plan, None),
        };
        (plan, plan_hit, arm)
    }

    /// Check a scratch arena out of the engine's pool (a fresh one
    /// when all are in flight — the pool grows to peak concurrency,
    /// then stops allocating).
    fn take_scratch(&self) -> Scratch {
        self.scratch
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .pop()
            .unwrap_or_default()
    }

    fn put_scratch(&self, scratch: Scratch) {
        self.scratch
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .push(scratch);
    }

    /// The shared dispatch body: validate, resolve the plan, execute
    /// into `scratch`, record telemetry, close the tuning loop.
    /// Allocation-free once the arena and the plan cache are warm.
    fn dispatch_into(
        &self,
        matrix_id: usize,
        xs: &[&[f64]],
        scratch: &mut Scratch,
    ) -> Result<BatchStats> {
        ensure!(!xs.is_empty(), "empty batch");
        let entry = self
            .registry
            .get(matrix_id)
            .ok_or_else(|| anyhow!("unknown matrix id {matrix_id}"))?;
        for x in xs {
            ensure!(
                x.len() == entry.csr.n_cols,
                "vector length {} != n_cols {} for matrix {}",
                x.len(),
                entry.csr.n_cols,
                entry.name
            );
        }
        let t_lookup = Instant::now();
        let (plan, plan_hit, arm) = self.plan_for_dispatch(entry);
        // Structural sanity gate (alloc-free, O(partition slots); on
        // by default in debug builds — `PlanConfig::validate`). A
        // corrupted plan becomes a counted error outcome on this
        // request instead of an out-of-bounds kernel write.
        if self.plans.config().validate {
            if let Err(why) = crate::check::quick_plan_check(&plan, &entry.csr)
            {
                return Err(anyhow!(
                    "plan validation failed for matrix {}: {why}",
                    entry.name
                ));
            }
        }
        let lookup_s = t_lookup.elapsed().as_secs_f64();
        let batch = xs.len();
        // Schedule attribution code of this dispatch (0 = none, else
        // `ladder::schedule_code + 1`) — also the pool workers'
        // kernel-span context.
        let sched_code = crate::autotune::ladder::schedule_code(
            plan.effective_schedule(batch),
        ) as usize
            + 1;
        if let Some(rec) = &self.trace {
            if rec.sampled() {
                let us = lookup_s * 1e6;
                let now = rec.now_us();
                rec.record(0, Stage::PlanLookup, sched_code, now - us, us);
                if !plan_hit {
                    // A miss spent the lookup interval building the
                    // plan: partitioning + format conversion.
                    rec.record(0, Stage::Partition, sched_code, now - us, us);
                }
            }
            rec.set_kernel_ctx(sched_code);
        }
        // Graceful degradation: the current ladder rung picks this
        // dispatch's execution path. `Sequential` bypasses the pool
        // entirely (a wedged pool must never wedge a request);
        // `ReducedLanes` keeps the pool — the stall mask already
        // narrows it — but the dispatch is counted as degraded.
        let mode = self.health.note_dispatch();
        if mode == DegradedMode::ReducedLanes {
            self.health.note_degraded_dispatch();
        }
        let pool = match mode {
            DegradedMode::Sequential => None,
            _ => self.pool.as_ref(),
        };
        // Scalability attribution: snapshot per-lane busy time around
        // the kernel so this dispatch can compute its own lane deltas
        // (max vs mean = load imbalance). Stack buffers — the dispatch
        // path stays allocation-free. Concurrent dispatches on one
        // pool smear each other's deltas slightly (same last-writer
        // contract as the kernel-span context); the aggregation
        // averages it out.
        let mut lanes_before = [0u64; MAX_LANES];
        let probed = match (self.scaling.is_enabled(), pool) {
            (true, Some(p)) => p.fill_busy_ns(&mut lanes_before),
            _ => 0,
        };
        let (wall_seconds, threads, per_request_ms) =
            if mode == DegradedMode::Sequential {
                // Last ladder rung: the direct sequential kernel into
                // the arena — no pool, no partition, same `row_dot`
                // accumulation order as the reference `Csr::spmv`.
                self.health.note_sequential_dispatch();
                let t0 = Instant::now();
                let n_rows = entry.csr.n_rows;
                if batch == 1 {
                    scratch.y.clear();
                    scratch.y.resize(n_rows, 0.0);
                    entry.csr.spmv(xs[0], &mut scratch.y);
                } else {
                    scratch.yb.clear();
                    scratch.yb.resize(n_rows * batch, 0.0);
                    scratch.y.clear();
                    scratch.y.resize(n_rows, 0.0);
                    for (j, x) in xs.iter().enumerate() {
                        entry.csr.spmv(x, &mut scratch.y);
                        for r in 0..n_rows {
                            scratch.yb[r * batch + j] = scratch.y[r];
                        }
                    }
                }
                let w = t0.elapsed().as_secs_f64();
                (w, 1, w * 1e3 / batch as f64)
            } else if batch == 1 {
                let st = plan.execute_into(&entry.csr, xs[0], pool, scratch);
                (st.wall_seconds, st.threads, st.per_request_ms())
            } else {
                let st =
                    plan.execute_batch_into(&entry.csr, xs, pool, scratch);
                (st.wall_seconds, st.threads, st.per_request_ms())
            };
        let (busy_max_s, busy_sum_s) = if probed > 0 {
            let mut lanes_after = [0u64; MAX_LANES];
            let n = pool
                .map_or(0, |p| p.fill_busy_ns(&mut lanes_after))
                .min(probed);
            let mut deltas = [0u64; MAX_LANES];
            let (mut max_ns, mut sum_ns) = (0u64, 0u64);
            for (i, (after, before)) in
                lanes_after[..n].iter().zip(&lanes_before[..n]).enumerate()
            {
                let d = after.saturating_sub(*before);
                deltas[i] = d;
                max_ns = max_ns.max(d);
                sum_ns += d;
            }
            // Feed the slow-lane EWMA detector (stack buffer — the
            // dispatch path stays allocation-free once the tracker's
            // lane vector is warm).
            self.health.observe_lanes(&deltas[..n]);
            (max_ns as f64 / 1e9, sum_ns as f64 / 1e9)
        } else {
            (0.0, 0.0)
        };
        if let Some(rec) = &self.trace {
            // Pool workers emit their own per-lane kernel spans; an
            // unpooled dispatch records the whole kernel at lane 0.
            if self.pool.is_none() {
                rec.record_elapsed(
                    0,
                    Stage::Kernel,
                    sched_code,
                    wall_seconds * 1e6,
                );
            }
        }
        let t_reduce = Instant::now();
        self.telemetry.record_batch(
            matrix_id,
            batch,
            wall_seconds,
            2.0 * entry.csr.nnz() as f64 * batch as f64,
            plan.effective_schedule_name(batch),
        );
        let reduce_s = t_reduce.elapsed().as_secs_f64();
        if let Some(rec) = &self.trace {
            rec.record_elapsed(0, Stage::Reduce, sched_code, reduce_s * 1e6);
        }
        self.obs.dispatches.inc();
        self.obs.latency_ms.observe(per_request_ms);
        self.obs.stage_us[Stage::PlanLookup.index()]
            .add((lookup_s * 1e6) as u64);
        self.obs.stage_us[Stage::Kernel.index()]
            .add((wall_seconds * 1e6) as u64);
        self.obs.stage_us[Stage::Reduce.index()]
            .add((reduce_s * 1e6) as u64);
        // Per-batch gap-to-linear decomposition (`obs::scaling`): the
        // dispatcher stage time measured so far is lookup + reduce;
        // the autotune-observe stage below is folded in post-hoc.
        let comps = GapComponents::from_executed(
            threads,
            wall_seconds,
            busy_max_s,
            busy_sum_s,
            lookup_s + reduce_s,
            probed > 0,
        );
        let mut tuner_obs_s = 0.0;
        // Close the loop on the engine's own clock (live serving).
        // External-clock tuners (virtual-time replay) are fed by the
        // caller instead — see `replay::Dispatcher`.
        if let (Some(t), Some(a)) = (&self.tuner, arm) {
            if t.wall_clock() && mode != DegradedMode::Full {
                // The ladder is not a plan property: a degraded
                // latency observed into the tuner would demote a good
                // plan, so observations are suppressed (not fed as
                // failures) until recovery.
                self.health.note_tuner_suppressed();
            } else if t.wall_clock() {
                let stages = StageObs {
                    plan_lookup_ms: lookup_s * 1e3,
                    kernel_ms: wall_seconds * 1e3,
                    reduce_ms: reduce_s * 1e3,
                    imbalance_ms: comps.imbalance_s * 1e3,
                    overhead_ms: comps.overhead_s * 1e3,
                    residual_ms: comps.residual_s.max(0.0) * 1e3,
                };
                let t_obs = Instant::now();
                if let Some(promoted) = t.observe_staged(
                    entry.fingerprint,
                    a,
                    per_request_ms,
                    batch,
                    &stages,
                ) {
                    self.plans.replace(entry.fingerprint, promoted);
                }
                let obs_s = t_obs.elapsed().as_secs_f64();
                tuner_obs_s = obs_s;
                if let Some(rec) = &self.trace {
                    rec.record_elapsed(
                        0,
                        Stage::AutotuneObserve,
                        sched_code,
                        obs_s * 1e6,
                    );
                }
                self.obs.stage_us[Stage::AutotuneObserve.index()]
                    .add((obs_s * 1e6) as u64);
            }
        }
        self.scaling.record(
            entry.fingerprint,
            threads,
            batch,
            &comps.with_extra_overhead(tuner_obs_s),
        );
        Ok(BatchStats {
            wall_seconds,
            plan_hit,
            schedule: plan.effective_schedule(batch),
            threads,
            arm,
        })
    }

    /// Serve a coalesced group of `y = A x` requests against one
    /// registered matrix, discarding the outputs — the steady-state
    /// serving path (queue drain loops, replay). Executes into a
    /// reused scratch arena: **zero heap allocations per request**
    /// once warm. `xs.len() == 1` takes the single-vector path;
    /// larger groups run as one multi-vector SpMM. Records batch
    /// telemetry; latency accounting is the caller's (it knows
    /// arrival times).
    pub fn serve_batch(
        &self,
        matrix_id: usize,
        xs: &[&[f64]],
    ) -> Result<BatchStats> {
        let mut scratch = self.take_scratch();
        let res = self.dispatch_into(matrix_id, xs, &mut scratch);
        self.put_scratch(scratch);
        res
    }

    /// [`ServeEngine::serve_batch`] with materialized outputs — for
    /// callers that consume the result vectors (tests, one-shot CLI
    /// paths). Pays one output clone per request on top of the
    /// scratch execution.
    pub fn execute_batch(
        &self,
        matrix_id: usize,
        xs: &[&[f64]],
    ) -> Result<BatchOutcome> {
        let mut scratch = self.take_scratch();
        let res = self.dispatch_into(matrix_id, xs, &mut scratch);
        let out = res.map(|stats| {
            let ys: Vec<Vec<f64>> = if xs.len() == 1 {
                vec![scratch.y().to_vec()]
            } else {
                let n_rows = scratch.y_batch().len() / xs.len();
                (0..xs.len())
                    .map(|j| scratch.batch_column(n_rows, xs.len(), j))
                    .collect()
            };
            BatchOutcome {
                ys,
                wall_seconds: stats.wall_seconds,
                plan_hit: stats.plan_hit,
                schedule: stats.schedule,
                threads: stats.threads,
                arm: stats.arm,
            }
        });
        self.put_scratch(scratch);
        out
    }

    /// One unified snapshot of every observability surface the engine
    /// carries — serving stats (including queue wait), plan-cache
    /// counters, executor-pool occupancy, autotune state, and the raw
    /// instrument registry — under one stable schema
    /// (`ft2000.metrics.v1`). Throughput inside `serve` uses the
    /// pool's uptime when pooled (0 otherwise; callers holding a real
    /// measurement window use `telemetry::report_json` directly).
    pub fn metrics_snapshot(&self) -> Json {
        let stats = self.telemetry.snapshot();
        let (hits, misses) = self.plans.stats();
        let duration_s = self.pool.as_ref().map_or(0.0, ExecPool::uptime_s);
        // Refresh the gauges the instrument registry also reports.
        let scratch_bytes: usize = {
            let arenas = self
                .scratch
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            arenas.iter().map(Scratch::footprint_bytes).sum()
        };
        self.metrics
            .gauge("serve.scratch.bytes")
            .set(scratch_bytes as f64);
        if let Some(rec) = &self.trace {
            // Ring-loss accounting: spans ever recorded vs still held.
            // The difference is what sampling consumers must know —
            // wrapped lanes silently overwrite their oldest spans.
            self.metrics
                .gauge("trace.spans.recorded")
                .set(rec.spans_recorded() as f64);
            self.metrics
                .gauge("trace.spans.overwritten")
                .set(rec.spans_overwritten() as f64);
            self.metrics
                .gauge("trace.sample")
                .set(rec.config().sample.max(1) as f64);
        }
        let pool_json = self.pool.as_ref().map(|pool| {
            let up = pool.uptime_s();
            let lanes: Vec<Json> = pool
                .worker_tallies()
                .into_iter()
                .enumerate()
                .map(|(i, (slots, busy_s))| {
                    let share = if up > 0.0 { busy_s / up } else { 0.0 };
                    self.metrics
                        .gauge(&format!("pool.lane{i}.busy_share"))
                        .set(share);
                    Json::Obj(
                        [
                            ("lane".to_string(), Json::Num(i as f64)),
                            ("slots".to_string(), Json::Num(slots as f64)),
                            ("busy_s".to_string(), Json::Num(busy_s)),
                            ("busy_share".to_string(), Json::Num(share)),
                        ]
                        .into_iter()
                        .collect(),
                    )
                })
                .collect();
            Json::Obj(
                [
                    (
                        "workers".to_string(),
                        Json::Num(pool.n_workers() as f64),
                    ),
                    (
                        "jobs".to_string(),
                        Json::Num(pool.jobs_dispatched() as f64),
                    ),
                    ("uptime_s".to_string(), Json::Num(up)),
                    ("lanes".to_string(), Json::Arr(lanes)),
                ]
                .into_iter()
                .collect(),
            )
        });
        let tune_json = self.tuner.as_ref().map(|t| {
            let (promotions, demotions) = t.totals();
            Json::Obj(
                [
                    (
                        "tuners".to_string(),
                        Json::Num(t.tuner_count() as f64),
                    ),
                    (
                        "promotions".to_string(),
                        Json::Num(promotions as f64),
                    ),
                    ("demotions".to_string(), Json::Num(demotions as f64)),
                    (
                        "dataset_rows".to_string(),
                        Json::Num(t.dataset_len() as f64),
                    ),
                    (
                        "summaries".to_string(),
                        crate::autotune::autotune_json(&t.summaries()),
                    ),
                ]
                .into_iter()
                .collect(),
            )
        });
        let mut obj = BTreeMap::new();
        obj.insert(
            "schema".to_string(),
            Json::Str("ft2000.metrics.v1".to_string()),
        );
        obj.insert(
            "serve".to_string(),
            telemetry::report_json(&stats, hits, misses, duration_s),
        );
        obj.insert(
            "plan_cache".to_string(),
            Json::Obj(
                [
                    ("hits".to_string(), Json::Num(hits as f64)),
                    ("misses".to_string(), Json::Num(misses as f64)),
                    (
                        "hit_rate".to_string(),
                        self.plans.hit_rate().map_or(Json::Null, Json::Num),
                    ),
                    (
                        "evictions".to_string(),
                        Json::Num(self.plans.evictions() as f64),
                    ),
                    (
                        "replacements".to_string(),
                        Json::Num(self.plans.replacements() as f64),
                    ),
                    ("len".to_string(), Json::Num(self.plans.len() as f64)),
                    (
                        "capacity".to_string(),
                        Json::Num(self.plans.capacity() as f64),
                    ),
                ]
                .into_iter()
                .collect(),
            ),
        );
        obj.insert("pool".to_string(), pool_json.unwrap_or(Json::Null));
        obj.insert("autotune".to_string(), tune_json.unwrap_or(Json::Null));
        obj.insert("registry".to_string(), self.metrics.snapshot());
        Json::Obj(obj)
    }

    /// The queue-wait summary the scalability snapshot embeds (the
    /// obs-report SLO-burn gate reads it).
    fn queue_wait_summary(stats: &ServeStats) -> QueueWaitSummary {
        QueueWaitSummary {
            p50_ms: stats.queue_wait.percentile(50.0).unwrap_or(0.0),
            p95_ms: stats.queue_wait.percentile(95.0).unwrap_or(0.0),
            mean_ms: stats.queue_wait.mean(),
            count: stats.queue_wait.count,
        }
    }

    /// The versioned `ft2000.scaling.v1` snapshot: the profiler's
    /// per-fingerprint gap attribution and efficiency curves plus the
    /// telemetry queue-wait summary — the document `ft2000-spmv
    /// obs-report` diffs for regressions.
    pub fn scaling_snapshot(&self) -> Json {
        let stats = self.telemetry.snapshot();
        self.scaling.snapshot(&Self::queue_wait_summary(&stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::generators;
    use crate::util::rng::Pcg32;

    fn engine_with(csrs: Vec<(&str, crate::sparse::Csr)>) -> ServeEngine {
        let mut reg = MatrixRegistry::new();
        for (name, csr) in csrs {
            reg.register(name, csr);
        }
        ServeEngine::new(reg, Planner::Heuristic, PlanConfig::default())
    }

    #[test]
    fn engine_serves_singletons_and_batches() {
        let mut rng = Pcg32::new(0xE0E0);
        let csr = generators::random_uniform(200, 6, &mut rng);
        let mut want = vec![0.0; 200];
        let x: Vec<f64> = (0..200).map(|_| rng.gen_f64()).collect();
        csr.spmv(&x, &mut want);
        let engine = engine_with(vec![("m", csr)]);

        let single = engine.execute_batch(0, &[&x]).unwrap();
        assert!(!single.plan_hit, "first request must build the plan");
        assert_eq!(single.ys.len(), 1);

        let batch = engine.execute_batch(0, &[&x, &x, &x]).unwrap();
        assert!(batch.plan_hit, "second request must hit the plan cache");
        assert_eq!(batch.ys.len(), 3);
        for y in single.ys.iter().chain(&batch.ys) {
            for (i, (a, b)) in want.iter().zip(y).enumerate() {
                assert!(
                    (a - b).abs() < 1e-9 * (1.0 + a.abs()),
                    "row {i}: {a} vs {b}"
                );
            }
        }
        let s = engine.telemetry.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.batches, 2);
        assert_eq!(engine.plans.stats(), (1, 1));
    }

    #[test]
    fn pooled_engine_matches_spawn_and_reuses_workers() {
        let mut rng = Pcg32::new(0xE0E4);
        let csr = generators::random_uniform(200, 6, &mut rng);
        let x: Vec<f64> = (0..200).map(|_| rng.gen_f64()).collect();
        let mut want = vec![0.0; 200];
        csr.spmv(&x, &mut want);
        let mut reg = MatrixRegistry::new();
        reg.register("m", csr);
        let engine =
            ServeEngine::pooled(reg, Planner::Heuristic, PlanConfig::default());
        assert!(engine.is_pooled());
        let workers = engine.pool().unwrap().n_workers();
        for _ in 0..25 {
            let out = engine.execute_batch(0, &[&x, &x]).unwrap();
            for y in &out.ys {
                for (i, (a, b)) in want.iter().zip(y).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-9 * (1.0 + a.abs()),
                        "row {i}: {a} vs {b}"
                    );
                }
            }
        }
        // Many small requests, zero thread growth: the reuse contract.
        assert_eq!(engine.pool().unwrap().n_workers(), workers);
        assert!(engine.pool().unwrap().jobs_dispatched() >= 25);
    }

    #[test]
    fn serve_batch_matches_execute_batch_semantics() {
        // The arena path must be observationally identical to the
        // materializing path: same plan decisions, same telemetry,
        // same error outcomes — it just skips the output vectors.
        let mut rng = Pcg32::new(0xE0E7);
        let csr = generators::random_uniform(180, 5, &mut rng);
        let x: Vec<f64> = (0..180).map(|_| rng.gen_f64()).collect();
        let mut reg = MatrixRegistry::new();
        reg.register("m", csr);
        let engine =
            ServeEngine::pooled(reg, Planner::Heuristic, PlanConfig::default());
        let first = engine.serve_batch(0, &[&x]).unwrap();
        assert!(!first.plan_hit, "first dispatch builds the plan");
        assert!(first.threads >= 1 && first.threads <= 4);
        let again = engine.serve_batch(0, &[&x, &x, &x]).unwrap();
        assert!(again.plan_hit);
        assert_eq!(again.schedule, {
            let (plan, _) = engine.plans.plan_for(
                engine.registry.entry(0).fingerprint,
                &engine.registry.entry(0).csr,
            );
            plan.effective_schedule(3)
        });
        let s = engine.telemetry.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.batches, 2);
        // Bad traffic errors identically to execute_batch.
        assert!(engine.serve_batch(9, &[&x]).is_err());
        assert!(engine.serve_batch(0, &[&x[..5]]).is_err());
        assert!(engine.serve_batch(0, &[]).is_err());
        // And the materializing path still returns correct outputs
        // after arena dispatches warmed the same scratch buffers.
        let out = engine.execute_batch(0, &[&x, &x]).unwrap();
        let entry = engine.registry.entry(0);
        let mut want = vec![0.0; 180];
        entry.csr.spmv(&x, &mut want);
        for y in &out.ys {
            for (i, (a, b)) in want.iter().zip(y).enumerate() {
                assert!(
                    (a - b).abs() < 1e-9 * (1.0 + a.abs()),
                    "row {i}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn batched_tile_plan_reports_effective_schedule() {
        // exdata_1 gets a CSR5 tile plan; batched dispatches remap to
        // CsrRowBalanced and telemetry must attribute them there.
        let csr = crate::corpus::NamedMatrix::Exdata1.generate();
        let n = csr.n_cols;
        let engine = engine_with(vec![("exdata", csr)]);
        let x = vec![1.0f64; n];
        let single = engine.execute_batch(0, &[&x]).unwrap();
        assert!(
            matches!(single.schedule, Schedule::Csr5Tiles { .. }),
            "singletons run the plan schedule: {:?}",
            single.schedule
        );
        let batch = engine.execute_batch(0, &[&x, &x]).unwrap();
        assert_eq!(
            batch.schedule,
            Schedule::CsrRowBalanced,
            "batches must report the executed row-space remap"
        );
        let s = engine.telemetry.snapshot();
        assert_eq!(s.per_schedule.get("csr-balanced"), Some(&2));
        assert_eq!(s.per_schedule.values().sum::<u64>(), 3);
    }

    #[test]
    fn tuned_engine_stays_correct_while_exploring() {
        use crate::autotune::AutotuneConfig;

        let mut rng = Pcg32::new(0xE0E6);
        let csr = generators::random_uniform(300, 6, &mut rng);
        let x: Vec<f64> = (0..300).map(|_| rng.gen_f64()).collect();
        let mut want = vec![0.0; 300];
        csr.spmv(&x, &mut want);
        let mut reg = MatrixRegistry::new();
        reg.register("m", csr);
        let engine =
            ServeEngine::new(reg, Planner::Heuristic, PlanConfig::default())
                .with_tuner(AutotuneConfig::default());
        assert!(engine.is_tuned());
        for i in 0..40 {
            let out = if i % 3 == 0 {
                engine.execute_batch(0, &[&x, &x]).unwrap()
            } else {
                engine.execute_batch(0, &[&x]).unwrap()
            };
            assert!(out.arm.is_some(), "tuned dispatches report their arm");
            for y in &out.ys {
                for (r, (a, b)) in want.iter().zip(y).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-9 * (1.0 + a.abs()),
                        "row {r}: {a} vs {b} while exploring"
                    );
                }
            }
        }
        let tuner = engine.tuner().unwrap();
        assert_eq!(tuner.tuner_count(), 1);
        let summaries = tuner.summaries();
        let s = &summaries[0];
        assert_eq!(s.observations, 40, "every dispatch must be observed");
        assert!(s.arms > 1, "the ladder must hold real alternatives");
        assert!(!tuner.dataset().is_empty());
    }

    #[test]
    fn metrics_snapshot_unifies_every_surface() {
        use crate::obs::{ClockMode, Stage, TraceConfig, TraceRecorder};
        let mut rng = Pcg32::new(0xE0E8);
        let csr = generators::random_uniform(160, 5, &mut rng);
        let x: Vec<f64> = (0..160).map(|_| rng.gen_f64()).collect();
        let mut reg = MatrixRegistry::new();
        reg.register("m", csr);
        let rec = Arc::new(TraceRecorder::new(
            TraceConfig::on(),
            ClockMode::Wall,
            5,
        ));
        let engine =
            ServeEngine::pooled(reg, Planner::Heuristic, PlanConfig::default())
                .with_tuner(crate::autotune::AutotuneConfig::default())
                .with_trace(rec.clone());
        for _ in 0..12 {
            engine.serve_batch(0, &[&x]).unwrap();
            engine.serve_batch(0, &[&x, &x]).unwrap();
        }
        engine.telemetry.record_queue_wait_ms(0.2);
        let snap = engine.metrics_snapshot();
        let parsed = crate::util::json::parse(&snap.to_string()).unwrap();
        assert_eq!(
            parsed.get("schema").unwrap().as_str(),
            Some("ft2000.metrics.v1")
        );
        let serve = parsed.get("serve").unwrap();
        assert_eq!(serve.get("requests").unwrap().as_usize(), Some(36));
        assert_eq!(
            serve
                .get("queue_wait_ms")
                .unwrap()
                .get("count")
                .unwrap()
                .as_usize(),
            Some(1)
        );
        let pc = parsed.get("plan_cache").unwrap();
        assert_eq!(pc.get("misses").unwrap().as_usize(), Some(1));
        assert!(pc.get("hits").unwrap().as_usize().unwrap() > 0);
        let pool = parsed.get("pool").unwrap();
        assert!(
            pool.get("lanes").unwrap().as_arr().unwrap().len() >= 2,
            "dispatcher lane + at least one worker lane"
        );
        let tune = parsed.get("autotune").unwrap();
        assert_eq!(tune.get("tuners").unwrap().as_usize(), Some(1));
        assert_eq!(tune.get("dataset_rows").unwrap().as_usize(), Some(24));
        let reg_snap = parsed.get("registry").unwrap();
        assert_eq!(
            reg_snap.get("serve.dispatches").unwrap().as_usize(),
            Some(24)
        );
        assert_eq!(
            reg_snap
                .get("serve.per_request_ms")
                .unwrap()
                .get("count")
                .unwrap()
                .as_usize(),
            Some(24)
        );
        assert!(
            reg_snap.get("serve.scratch.bytes").unwrap().as_f64().unwrap()
                > 0.0,
            "warmed arenas must report a footprint"
        );
        // The dispatch path recorded its engine-side stage spans, and
        // the pool its kernel spans.
        let cells = rec.flame_cells();
        for stage in [
            Stage::PlanLookup,
            Stage::Partition,
            Stage::Kernel,
            Stage::Reduce,
            Stage::AutotuneObserve,
        ] {
            assert!(
                cells.keys().any(|(s, _)| *s == stage.index()),
                "missing {} spans",
                stage.name()
            );
        }
    }

    #[test]
    fn engine_rejects_bad_requests() {
        let mut rng = Pcg32::new(0xE0E1);
        let csr = generators::banded(64, 3, &mut rng);
        let engine = engine_with(vec![("m", csr)]);
        assert!(engine.execute_batch(9, &[&[0.0; 64]]).is_err());
        assert!(engine.execute_batch(0, &[&[0.0; 5]]).is_err());
        assert!(engine.execute_batch(0, &[]).is_err());
    }

    #[test]
    fn worker_pool_end_to_end() {
        let mut rng = Pcg32::new(0xE0E2);
        let a = generators::banded(128, 3, &mut rng);
        let b = generators::random_uniform(128, 4, &mut rng);
        let engine = engine_with(vec![("a", a), ("b", b)]);
        let queue = RequestQueue::new();
        for i in 0..40 {
            queue.push(Request::new(i % 2, vec![1.0; 128]));
        }
        queue.close();
        let served = serve_queue(&engine, &queue, 2, 8);
        assert_eq!(served, 40);
        let s = engine.telemetry.snapshot();
        assert_eq!(s.requests, 40);
        assert_eq!(s.latencies_ms.len(), 40);
        assert!(s.batches < 40, "coalescing must form some batches");
        let (hits, misses) = engine.plans.stats();
        assert_eq!(misses, 2, "one plan build per matrix");
        assert!(hits > 0);
    }

    #[test]
    fn pooled_worker_pool_end_to_end() {
        // Same drain loop as worker_pool_end_to_end, but the engine
        // executes on its resident ExecPool: many small requests, no
        // per-request spawn, identical serving semantics.
        let mut rng = Pcg32::new(0xE0E5);
        let a = generators::banded(128, 3, &mut rng);
        let b = generators::random_uniform(128, 4, &mut rng);
        let mut reg = MatrixRegistry::new();
        reg.register("a", a);
        reg.register("b", b);
        let engine =
            ServeEngine::pooled(reg, Planner::Heuristic, PlanConfig::default());
        let workers_before = engine.pool().unwrap().n_workers();
        let queue = RequestQueue::new();
        for i in 0..40 {
            queue.push(Request::new(i % 2, vec![1.0; 128]));
        }
        queue.close();
        let served = serve_queue(&engine, &queue, 2, 8);
        assert_eq!(served, 40);
        let s = engine.telemetry.snapshot();
        assert_eq!(s.requests, 40);
        assert_eq!(s.latencies_ms.len(), 40);
        let pool = engine.pool().unwrap();
        assert_eq!(
            pool.n_workers(),
            workers_before,
            "40 requests must not grow the resident worker set"
        );
        assert!(
            pool.jobs_dispatched() > 0,
            "drained batches must run on the pool"
        );
    }

    #[test]
    fn poison_request_does_not_kill_the_pool() {
        // Regression: a request against an unregistered matrix id used
        // to `.expect()` inside a scoped worker and abort the whole
        // server. It must be an error outcome while valid traffic
        // keeps flowing.
        let mut rng = Pcg32::new(0xE0E3);
        let a = generators::banded(96, 3, &mut rng);
        let engine = engine_with(vec![("a", a)]);
        let queue = RequestQueue::new();
        for i in 0..20 {
            if i == 7 {
                queue.push(Request::new(999, vec![1.0; 96])); // poison id
            }
            if i == 13 {
                queue.push(Request::new(0, vec![1.0; 5])); // bad length
            }
            queue.push(Request::new(0, vec![1.0; 96]));
        }
        queue.close();
        let served = serve_queue(&engine, &queue, 2, 4);
        assert_eq!(served, 20, "valid traffic must all be served");
        let s = engine.telemetry.snapshot();
        assert_eq!(s.requests, 20);
        assert!(
            s.errors >= 2,
            "both poison requests must be counted: {}",
            s.errors
        );
        assert_eq!(s.digest.count, 20, "latencies only for served requests");
    }
}
