//! Batched SpMV serving subsystem — the request path of the engine.
//!
//! The paper's conclusion (and SpChar's after it) is that the right
//! format/schedule/thread placement for SpMV is a *per-matrix*
//! decision. A characterization harness makes that decision once per
//! experiment; a serving system must make it once per *matrix* and
//! then sustain heavy request traffic against it. This module adds
//! that layer:
//!
//! * [`registry`] — content-fingerprinted store of loaded matrices
//!   with precomputed features (load once, serve forever);
//! * [`plan`] — per-fingerprint memoized execution plans: schedule
//!   choice (heuristic thresholds or the learned
//!   `coordinator::format_select` tree), thread count/placement, and
//!   the pre-converted CSR5 structure when tiles win — with hit/miss
//!   accounting;
//! * [`batch`] — per-matrix-indexed request queue (optionally
//!   bounded) + worker pool that coalesces concurrent `y = A x`
//!   requests against the same matrix into one multi-vector
//!   `exec::spmm_threaded` launch (single-vector `spmv_threaded` for
//!   singletons); bad requests are error outcomes, not panics;
//! * [`shard`] — the panel-aware sharded server: per-shard queues,
//!   plan-cache views and telemetry, popularity/size placement with
//!   hot-matrix replication, bounded-queue admission control and
//!   deadline shedding (the paper's NUMA-panel topology, Fig 3,
//!   applied to serving);
//! * [`workload`] — deterministic open-loop (Poisson, bursty) and
//!   closed-loop traffic generators with uniform or Zipf matrix
//!   popularity;
//! * [`replay`] — virtual-time replay of a workload through the
//!   engine: deterministic latency percentiles from an explicit cost
//!   model, real kernel executions for measured throughput;
//! * [`telemetry`] — the serving report (throughput, p50/p95/p99,
//!   batch histogram, plan-cache hit rate) in table and JSON form.

pub mod batch;
pub mod plan;
pub mod registry;
pub mod replay;
pub mod shard;
pub mod telemetry;
pub mod workload;

pub use batch::{serve_queue, PushError, Request, RequestQueue};
pub use plan::{build_plan, Plan, PlanCache, PlanConfig, PlannedFormat, Planner};
pub use registry::{fingerprint, MatrixEntry, MatrixRegistry};
pub use replay::{
    replay, replay_sharded, CostModel, ReplayConfig, ReplayReport,
    ShardedReplayReport,
};
pub use shard::{
    Admitted, PlacementPolicy, Shard, ShardConfig, ShardPlacement,
    ShardedServer,
};
pub use telemetry::{ServeStats, ShardSnapshot, Telemetry};
pub use workload::{Arrivals, GenRequest, Popularity, WorkloadSpec};

use std::sync::Arc;

use anyhow::{anyhow, ensure, Result};

use crate::exec;
use crate::sched::Schedule;

/// Outcome of one (possibly coalesced) execution.
pub struct BatchOutcome {
    /// One output vector per request, in request order.
    pub ys: Vec<Vec<f64>>,
    pub wall_seconds: f64,
    pub plan_hit: bool,
    pub schedule: Schedule,
    pub threads: usize,
}

/// The serving engine: registry + plan cache + telemetry. Shared by
/// reference across worker threads (all interior state is locked).
/// The registry is behind an `Arc` so a sharded deployment can give
/// every shard its own engine view (private plan cache + telemetry)
/// over one loaded matrix store.
pub struct ServeEngine {
    pub registry: Arc<MatrixRegistry>,
    pub plans: PlanCache,
    pub telemetry: Telemetry,
}

impl ServeEngine {
    pub fn new(
        registry: MatrixRegistry,
        planner: Planner,
        cfg: PlanConfig,
    ) -> Self {
        Self::shared(Arc::new(registry), planner, cfg)
    }

    /// Engine view over an already-shared registry (one per shard).
    pub fn shared(
        registry: Arc<MatrixRegistry>,
        planner: Planner,
        cfg: PlanConfig,
    ) -> Self {
        ServeEngine {
            registry,
            plans: PlanCache::new(planner, cfg),
            telemetry: Telemetry::new(),
        }
    }

    /// Execute a coalesced group of `y = A x` requests against one
    /// registered matrix. `xs.len() == 1` takes the single-vector
    /// path; larger groups run as one multi-vector SpMM. Records
    /// batch telemetry; latency accounting is the caller's (it knows
    /// arrival times).
    pub fn execute_batch(
        &self,
        matrix_id: usize,
        xs: &[&[f64]],
    ) -> Result<BatchOutcome> {
        ensure!(!xs.is_empty(), "empty batch");
        let entry = self
            .registry
            .get(matrix_id)
            .ok_or_else(|| anyhow!("unknown matrix id {matrix_id}"))?;
        for x in xs {
            ensure!(
                x.len() == entry.csr.n_cols,
                "vector length {} != n_cols {} for matrix {}",
                x.len(),
                entry.csr.n_cols,
                entry.name
            );
        }
        let (plan, plan_hit) =
            self.plans.plan_for(entry.fingerprint, &entry.csr);
        let (ys, wall_seconds, threads) = if xs.len() == 1 {
            let r = plan.execute(&entry.csr, xs[0]);
            (vec![r.y], r.wall_seconds, r.threads)
        } else {
            let packed = exec::pack_vectors(xs);
            let r = plan.execute_batch(&entry.csr, &packed, xs.len());
            let ys = (0..xs.len()).map(|j| r.column(j)).collect();
            (ys, r.wall_seconds, r.threads)
        };
        self.telemetry.record_batch(
            matrix_id,
            xs.len(),
            wall_seconds,
            2.0 * entry.csr.nnz() as f64 * xs.len() as f64,
        );
        Ok(BatchOutcome {
            ys,
            wall_seconds,
            plan_hit,
            schedule: plan.schedule,
            threads,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::generators;
    use crate::util::rng::Pcg32;

    fn engine_with(csrs: Vec<(&str, crate::sparse::Csr)>) -> ServeEngine {
        let mut reg = MatrixRegistry::new();
        for (name, csr) in csrs {
            reg.register(name, csr);
        }
        ServeEngine::new(reg, Planner::Heuristic, PlanConfig::default())
    }

    #[test]
    fn engine_serves_singletons_and_batches() {
        let mut rng = Pcg32::new(0xE0E0);
        let csr = generators::random_uniform(200, 6, &mut rng);
        let mut want = vec![0.0; 200];
        let x: Vec<f64> = (0..200).map(|_| rng.gen_f64()).collect();
        csr.spmv(&x, &mut want);
        let engine = engine_with(vec![("m", csr)]);

        let single = engine.execute_batch(0, &[&x]).unwrap();
        assert!(!single.plan_hit, "first request must build the plan");
        assert_eq!(single.ys.len(), 1);

        let batch = engine.execute_batch(0, &[&x, &x, &x]).unwrap();
        assert!(batch.plan_hit, "second request must hit the plan cache");
        assert_eq!(batch.ys.len(), 3);
        for y in single.ys.iter().chain(&batch.ys) {
            for (i, (a, b)) in want.iter().zip(y).enumerate() {
                assert!(
                    (a - b).abs() < 1e-9 * (1.0 + a.abs()),
                    "row {i}: {a} vs {b}"
                );
            }
        }
        let s = engine.telemetry.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.batches, 2);
        assert_eq!(engine.plans.stats(), (1, 1));
    }

    #[test]
    fn engine_rejects_bad_requests() {
        let mut rng = Pcg32::new(0xE0E1);
        let csr = generators::banded(64, 3, &mut rng);
        let engine = engine_with(vec![("m", csr)]);
        assert!(engine.execute_batch(9, &[&[0.0; 64]]).is_err());
        assert!(engine.execute_batch(0, &[&[0.0; 5]]).is_err());
        assert!(engine.execute_batch(0, &[]).is_err());
    }

    #[test]
    fn worker_pool_end_to_end() {
        let mut rng = Pcg32::new(0xE0E2);
        let a = generators::banded(128, 3, &mut rng);
        let b = generators::random_uniform(128, 4, &mut rng);
        let engine = engine_with(vec![("a", a), ("b", b)]);
        let queue = RequestQueue::new();
        for i in 0..40 {
            queue.push(Request::new(i % 2, vec![1.0; 128]));
        }
        queue.close();
        let served = serve_queue(&engine, &queue, 2, 8);
        assert_eq!(served, 40);
        let s = engine.telemetry.snapshot();
        assert_eq!(s.requests, 40);
        assert_eq!(s.latencies_ms.len(), 40);
        assert!(s.batches < 40, "coalescing must form some batches");
        let (hits, misses) = engine.plans.stats();
        assert_eq!(misses, 2, "one plan build per matrix");
        assert!(hits > 0);
    }

    #[test]
    fn poison_request_does_not_kill_the_pool() {
        // Regression: a request against an unregistered matrix id used
        // to `.expect()` inside a scoped worker and abort the whole
        // server. It must be an error outcome while valid traffic
        // keeps flowing.
        let mut rng = Pcg32::new(0xE0E3);
        let a = generators::banded(96, 3, &mut rng);
        let engine = engine_with(vec![("a", a)]);
        let queue = RequestQueue::new();
        for i in 0..20 {
            if i == 7 {
                queue.push(Request::new(999, vec![1.0; 96])); // poison id
            }
            if i == 13 {
                queue.push(Request::new(0, vec![1.0; 5])); // bad length
            }
            queue.push(Request::new(0, vec![1.0; 96]));
        }
        queue.close();
        let served = serve_queue(&engine, &queue, 2, 4);
        assert_eq!(served, 20, "valid traffic must all be served");
        let s = engine.telemetry.snapshot();
        assert_eq!(s.requests, 20);
        assert!(
            s.errors >= 2,
            "both poison requests must be counted: {}",
            s.errors
        );
        assert_eq!(s.digest.count, 20, "latencies only for served requests");
    }
}
