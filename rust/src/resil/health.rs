//! Health tracking, the degraded-mode ladder, and the versioned
//! `ft2000.health.v1` evidence snapshot.
//!
//! Every fault the injection plane can raise must end here as a
//! *counted* outcome: a [`HealthTracker`] is the single ledger a
//! serve path (engine or shard router) writes its graceful-
//! degradation decisions into — sheds, bounded retries, failovers,
//! contained panics, degraded and sequential dispatches, slow-lane
//! marks from the EWMA straggler detector. Trackers merge across
//! shards exactly like `obs::scaling` profilers merge, and
//! [`compare_health`] diffs two snapshots into counted regression
//! findings (recovery-time p95, shed rate, degraded-mode dwell) for
//! the `obs-report` gate.
//!
//! Steady-state discipline matches the rest of the serve path: one
//! poison-recovering mutex, counter bumps only, the per-lane EWMA
//! vector grown once during warmup — the zero-alloc pin in
//! `tests/alloc.rs` covers the tracker with serving live.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::check::{CheckReport, Finding};
use crate::service::telemetry::LatencyDigest;
use crate::util::json::Json;

use super::FaultKind;

/// Version tag of the health snapshot document.
pub const HEALTH_SCHEMA: &str = "ft2000.health.v1";

/// EWMA smoothing for per-lane busy shares.
const EWMA_ALPHA: f64 = 0.2;

/// Dispatches observed before the slow-lane detector may mark
/// anyone (EWMA warmup).
const SLOW_LANE_WARMUP: u64 = 8;

/// The degradation ladder. Ordered: escalation only ever moves
/// right, recovery returns to [`DegradedMode::Full`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum DegradedMode {
    /// Healthy: dispatch on the full executor pool.
    Full,
    /// Some lanes are stalled/slow: the pool runs narrowed (the
    /// stall mask keeps sick lanes from claiming), autotune
    /// observations are suppressed so the ladder is not mistaken for
    /// a plan regression.
    ReducedLanes,
    /// Last rung: bypass the pool entirely and run the sequential
    /// fallback kernel — degraded throughput, never a wedge.
    Sequential,
}

impl DegradedMode {
    pub fn name(&self) -> &'static str {
        match self {
            DegradedMode::Full => "full",
            DegradedMode::ReducedLanes => "reduced_lanes",
            DegradedMode::Sequential => "sequential",
        }
    }

    fn index(&self) -> usize {
        match self {
            DegradedMode::Full => 0,
            DegradedMode::ReducedLanes => 1,
            DegradedMode::Sequential => 2,
        }
    }
}

/// Copyable counter roll-up for assertions and quick reads.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HealthTotals {
    pub served_ok: u64,
    pub shed: u64,
    pub retried: u64,
    pub rejected: u64,
    pub rejected_corrupt: u64,
    pub failed_over: u64,
    pub degraded_dispatches: u64,
    pub sequential_dispatches: u64,
    pub tuner_suppressed: u64,
    pub panics_contained: u64,
    pub slow_lane_marks: u64,
    pub injected_total: u64,
}

#[derive(Clone)]
struct HealthState {
    injected: [u64; FaultKind::ALL.len()],
    served_ok: u64,
    shed: u64,
    retried: u64,
    rejected: u64,
    rejected_corrupt: u64,
    failed_over: u64,
    degraded_dispatches: u64,
    sequential_dispatches: u64,
    tuner_suppressed: u64,
    panics_contained: u64,
    slow_lane_marks: u64,
    /// Dispatches the EWMA detector has observed (warmup gate).
    lanes_observed: u64,
    /// Per-lane EWMA of the busy share; grown once on first observe
    /// (warmup-time allocation, like the scaling profiler's maps).
    lane_ewma: Vec<f64>,
    mode: DegradedMode,
    /// Dispatch counts spent on each ladder rung.
    mode_dwell: [u64; 3],
    /// Virtual/relative timestamp of the Full → degraded transition;
    /// cleared (into the recovery digest) on recovery.
    escalated_at_ms: Option<f64>,
    /// Escalation → recovery durations, ms.
    recovery: LatencyDigest,
}

/// The fault/recovery ledger of one serve surface (an engine, a
/// shard router, or a chaos driver). All methods take `&self`:
/// mutation is behind one poison-recovering mutex, and the
/// steady-state cost is a lock plus counter bumps.
pub struct HealthTracker {
    inner: Mutex<HealthState>,
}

impl Default for HealthTracker {
    fn default() -> Self {
        Self::new()
    }
}

impl HealthTracker {
    pub fn new() -> HealthTracker {
        HealthTracker {
            inner: Mutex::new(HealthState {
                injected: [0; FaultKind::ALL.len()],
                served_ok: 0,
                shed: 0,
                retried: 0,
                rejected: 0,
                rejected_corrupt: 0,
                failed_over: 0,
                degraded_dispatches: 0,
                sequential_dispatches: 0,
                tuner_suppressed: 0,
                panics_contained: 0,
                slow_lane_marks: 0,
                lanes_observed: 0,
                lane_ewma: Vec::new(),
                mode: DegradedMode::Full,
                mode_dwell: [0; 3],
                escalated_at_ms: None,
                recovery: LatencyDigest::default(),
            }),
        }
    }

    /// Lock the state, recovering from poisoning — the guarded
    /// sections are pure field updates (same rationale as the pool's
    /// state lock).
    fn lock(&self) -> std::sync::MutexGuard<'_, HealthState> {
        self.inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Count one injected fault of `kind`.
    pub fn note_injected(&self, kind: FaultKind) {
        self.lock().injected[kind.index()] += 1;
    }

    pub fn note_served(&self, n: u64) {
        self.lock().served_ok += n;
    }

    pub fn note_shed(&self, n: u64) {
        self.lock().shed += n;
    }

    pub fn note_retried(&self, n: u64) {
        self.lock().retried += n;
    }

    pub fn note_rejected(&self, n: u64) {
        self.lock().rejected += n;
    }

    pub fn note_rejected_corrupt(&self, n: u64) {
        self.lock().rejected_corrupt += n;
    }

    pub fn note_failed_over(&self, n: u64) {
        self.lock().failed_over += n;
    }

    pub fn note_panic_contained(&self) {
        self.lock().panics_contained += 1;
    }

    /// Count one dispatch issued while some lane was degraded (the
    /// pool ran narrowed).
    pub fn note_degraded_dispatch(&self) {
        self.lock().degraded_dispatches += 1;
    }

    /// Count one dispatch forced onto the sequential fallback.
    pub fn note_sequential_dispatch(&self) {
        self.lock().sequential_dispatches += 1;
    }

    /// Count one autotune observation suppressed by the ladder.
    pub fn note_tuner_suppressed(&self) {
        self.lock().tuner_suppressed += 1;
    }

    /// Called at the top of every dispatch: charges the dwell
    /// counter of the current rung and returns it so the dispatcher
    /// can pick its execution path.
    pub fn note_dispatch(&self) -> DegradedMode {
        let mut st = self.lock();
        let mode = st.mode;
        st.mode_dwell[mode.index()] += 1;
        mode
    }

    /// Feed one dispatch's per-lane busy deltas (nanoseconds) into
    /// the EWMA straggler detector. Alloc-free after the first call
    /// at a given width. A lane whose smoothed share sits under half
    /// its fair share (after warmup) earns a slow-lane mark.
    pub fn observe_lanes(&self, busy: &[u64]) {
        let n = busy.len();
        if n == 0 {
            return;
        }
        let total: u64 = busy.iter().sum();
        if total == 0 {
            return;
        }
        let mut st = self.lock();
        if st.lane_ewma.len() < n {
            let fair = 1.0 / n as f64;
            st.lane_ewma.resize(n, fair);
        }
        st.lanes_observed += 1;
        let warmed = st.lanes_observed >= SLOW_LANE_WARMUP;
        let fair = 1.0 / n as f64;
        for (i, &b) in busy.iter().enumerate() {
            let share = b as f64 / total as f64;
            let updated =
                st.lane_ewma[i] * (1.0 - EWMA_ALPHA) + share * EWMA_ALPHA;
            st.lane_ewma[i] = updated;
            if warmed && n >= 2 && updated < 0.5 * fair {
                st.slow_lane_marks += 1;
            }
        }
    }

    /// Climb the ladder to `to` (escalation is monotone; a request
    /// to move down is ignored — that is what [`Self::recover`] is
    /// for). `now_ms` stamps the start of the degraded window on the
    /// first rung up.
    pub fn escalate(&self, to: DegradedMode, now_ms: f64) {
        let mut st = self.lock();
        if to <= st.mode {
            return;
        }
        if st.mode == DegradedMode::Full {
            st.escalated_at_ms = Some(now_ms);
        }
        st.mode = to;
    }

    /// Return to [`DegradedMode::Full`], observing the degraded
    /// window's duration into the recovery digest.
    pub fn recover(&self, now_ms: f64) {
        let mut st = self.lock();
        if st.mode == DegradedMode::Full {
            return;
        }
        if let Some(t0) = st.escalated_at_ms.take() {
            let dt = (now_ms - t0).max(0.0);
            st.recovery.observe(dt);
        }
        st.mode = DegradedMode::Full;
    }

    pub fn mode(&self) -> DegradedMode {
        self.lock().mode
    }

    pub fn totals(&self) -> HealthTotals {
        let st = self.lock();
        HealthTotals {
            served_ok: st.served_ok,
            shed: st.shed,
            retried: st.retried,
            rejected: st.rejected,
            rejected_corrupt: st.rejected_corrupt,
            failed_over: st.failed_over,
            degraded_dispatches: st.degraded_dispatches,
            sequential_dispatches: st.sequential_dispatches,
            tuner_suppressed: st.tuner_suppressed,
            panics_contained: st.panics_contained,
            slow_lane_marks: st.slow_lane_marks,
            injected_total: st.injected.iter().sum(),
        }
    }

    /// Fold another tracker into this one (fleet roll-ups, the same
    /// merge idiom as `ScalingProfiler::merge_from`). Counters and
    /// dwell add, digests merge, the mode takes the worse rung, and
    /// lane EWMAs average where both sides observed the lane.
    pub fn merge_from(&self, other: &HealthTracker) {
        let o = { other.lock().clone() };
        let mut st = self.lock();
        for (mine, theirs) in st.injected.iter_mut().zip(o.injected) {
            *mine += theirs;
        }
        st.served_ok += o.served_ok;
        st.shed += o.shed;
        st.retried += o.retried;
        st.rejected += o.rejected;
        st.rejected_corrupt += o.rejected_corrupt;
        st.failed_over += o.failed_over;
        st.degraded_dispatches += o.degraded_dispatches;
        st.sequential_dispatches += o.sequential_dispatches;
        st.tuner_suppressed += o.tuner_suppressed;
        st.panics_contained += o.panics_contained;
        st.slow_lane_marks += o.slow_lane_marks;
        st.lanes_observed += o.lanes_observed;
        let had = st.lane_ewma.len();
        if had < o.lane_ewma.len() {
            st.lane_ewma.resize(o.lane_ewma.len(), 0.0);
        }
        for (i, &v) in o.lane_ewma.iter().enumerate() {
            if i < had {
                st.lane_ewma[i] = 0.5 * (st.lane_ewma[i] + v);
            } else {
                st.lane_ewma[i] = v;
            }
        }
        st.mode = st.mode.max(o.mode);
        for (mine, theirs) in st.mode_dwell.iter_mut().zip(o.mode_dwell) {
            *mine += theirs;
        }
        st.recovery.merge(&o.recovery);
    }

    /// The versioned `ft2000.health.v1` document.
    pub fn snapshot(&self) -> Json {
        let st = self.lock().clone();
        let mut doc = BTreeMap::new();
        doc.insert(
            "schema".to_string(),
            Json::Str(HEALTH_SCHEMA.to_string()),
        );
        let mut injected = BTreeMap::new();
        for k in FaultKind::ALL {
            injected.insert(
                k.name().to_string(),
                Json::Num(st.injected[k.index()] as f64),
            );
        }
        doc.insert("injected".to_string(), Json::Obj(injected));
        let mut outcomes = BTreeMap::new();
        for (key, v) in [
            ("served_ok", st.served_ok),
            ("shed", st.shed),
            ("retried", st.retried),
            ("rejected", st.rejected),
            ("rejected_corrupt", st.rejected_corrupt),
            ("failed_over", st.failed_over),
            ("degraded_dispatches", st.degraded_dispatches),
            ("sequential_dispatches", st.sequential_dispatches),
            ("tuner_suppressed", st.tuner_suppressed),
            ("panics_contained", st.panics_contained),
            ("slow_lane_marks", st.slow_lane_marks),
        ] {
            outcomes.insert(key.to_string(), Json::Num(v as f64));
        }
        doc.insert("outcomes".to_string(), Json::Obj(outcomes));
        let mut mode = BTreeMap::new();
        mode.insert(
            "current".to_string(),
            Json::Str(st.mode.name().to_string()),
        );
        let mut dwell = BTreeMap::new();
        dwell.insert(
            "full".to_string(),
            Json::Num(st.mode_dwell[0] as f64),
        );
        dwell.insert(
            "reduced_lanes".to_string(),
            Json::Num(st.mode_dwell[1] as f64),
        );
        dwell.insert(
            "sequential".to_string(),
            Json::Num(st.mode_dwell[2] as f64),
        );
        mode.insert("dwell".to_string(), Json::Obj(dwell));
        doc.insert("mode".to_string(), Json::Obj(mode));
        let mut rec = BTreeMap::new();
        rec.insert(
            "count".to_string(),
            Json::Num(st.recovery.count as f64),
        );
        rec.insert("mean_ms".to_string(), Json::Num(st.recovery.mean()));
        rec.insert("max_ms".to_string(), Json::Num(st.recovery.max_ms));
        rec.insert(
            "p50_ms".to_string(),
            Json::Num(st.recovery.percentile(50.0).unwrap_or(0.0)),
        );
        rec.insert(
            "p95_ms".to_string(),
            Json::Num(st.recovery.percentile(95.0).unwrap_or(0.0)),
        );
        doc.insert("recovery_ms".to_string(), Json::Obj(rec));
        let lanes: Vec<Json> = st
            .lane_ewma
            .iter()
            .enumerate()
            .map(|(i, &e)| {
                let mut lane = BTreeMap::new();
                lane.insert("lane".to_string(), Json::Num(i as f64));
                lane.insert("ewma_share".to_string(), Json::Num(e));
                Json::Obj(lane)
            })
            .collect();
        doc.insert("lanes".to_string(), Json::Arr(lanes));
        Json::Obj(doc)
    }
}

/// Regression thresholds for [`compare_health`].
#[derive(Clone, Copy, Debug)]
pub struct HealthThresholds {
    /// Absolute recovery-p95 ceiling, ms. `None` derives
    /// `2 * baseline_p95 + 1.0` — generous for short windows, tight
    /// once recoveries exist (the scaling gate's queue-wait rule).
    pub recovery_p95_ms: Option<f64>,
    /// Allowed absolute increase of `shed / (served_ok + shed)`.
    pub shed_rate_drift: f64,
    /// Allowed absolute increase of the degraded-dwell fraction
    /// (`(reduced + sequential) / total` dispatches).
    pub dwell_drift: f64,
}

impl Default for HealthThresholds {
    fn default() -> Self {
        HealthThresholds {
            recovery_p95_ms: None,
            shed_rate_drift: 0.05,
            dwell_drift: 0.10,
        }
    }
}

fn check(
    report: &mut CheckReport,
    ok: bool,
    subject: String,
    invariant: &'static str,
    detail: impl FnOnce() -> String,
) {
    report.checked += 1;
    if !ok {
        report.findings.push(Finding {
            subject,
            invariant,
            detail: detail(),
        });
    }
}

fn num(doc: &Json, path: &[&str]) -> Option<f64> {
    let mut cur = doc;
    for k in path {
        cur = cur.get(k)?;
    }
    cur.as_f64()
}

fn shed_rate(doc: &Json) -> f64 {
    let served = num(doc, &["outcomes", "served_ok"]).unwrap_or(0.0);
    let shed = num(doc, &["outcomes", "shed"]).unwrap_or(0.0);
    if served + shed <= 0.0 {
        0.0
    } else {
        shed / (served + shed)
    }
}

fn dwell_fraction(doc: &Json) -> f64 {
    let full = num(doc, &["mode", "dwell", "full"]).unwrap_or(0.0);
    let reduced =
        num(doc, &["mode", "dwell", "reduced_lanes"]).unwrap_or(0.0);
    let seq = num(doc, &["mode", "dwell", "sequential"]).unwrap_or(0.0);
    let total = full + reduced + seq;
    if total <= 0.0 {
        0.0
    } else {
        (reduced + seq) / total
    }
}

/// Diff two `ft2000.health.v1` snapshots into counted regression
/// findings: recovery-time p95 past its ceiling, shed rate drifting
/// up, degraded-mode dwell growing. Schema mismatches short-circuit
/// — comparing across versions would silently check nothing.
pub fn compare_health(
    baseline: &Json,
    current: &Json,
    th: &HealthThresholds,
) -> CheckReport {
    let mut report = CheckReport::new();
    for (tag, doc) in [("baseline", baseline), ("current", current)] {
        check(
            &mut report,
            doc.get("schema").and_then(Json::as_str) == Some(HEALTH_SCHEMA),
            format!("{tag} health snapshot"),
            "health-schema",
            || {
                format!(
                    "expected schema \"{HEALTH_SCHEMA}\", got {:?}",
                    doc.get("schema").and_then(Json::as_str)
                )
            },
        );
    }
    if !report.is_clean() {
        return report;
    }

    let base_p95 = num(baseline, &["recovery_ms", "p95_ms"]).unwrap_or(0.0);
    let cur_p95 = num(current, &["recovery_ms", "p95_ms"]).unwrap_or(0.0);
    let ceiling = th.recovery_p95_ms.unwrap_or(2.0 * base_p95 + 1.0);
    check(
        &mut report,
        cur_p95 <= ceiling,
        "recovery p95".to_string(),
        "recovery-p95",
        || {
            format!(
                "recovery p95 {cur_p95:.3} ms exceeds the allowed \
                 {ceiling:.3} ms (baseline {base_p95:.3} ms)"
            )
        },
    );

    let base_shed = shed_rate(baseline);
    let cur_shed = shed_rate(current);
    check(
        &mut report,
        cur_shed <= base_shed + th.shed_rate_drift,
        "shed rate".to_string(),
        "shed-rate",
        || {
            format!(
                "shed rate rose {base_shed:.4} -> {cur_shed:.4} \
                 (allowed drift {:.4})",
                th.shed_rate_drift
            )
        },
    );

    let base_dwell = dwell_fraction(baseline);
    let cur_dwell = dwell_fraction(current);
    check(
        &mut report,
        cur_dwell <= base_dwell + th.dwell_drift,
        "degraded-mode dwell".to_string(),
        "degraded-dwell",
        || {
            format!(
                "degraded dwell fraction rose {base_dwell:.4} -> \
                 {cur_dwell:.4} (allowed drift {:.4})",
                th.dwell_drift
            )
        },
    );
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_escalates_monotonically_and_recovers_with_timing() {
        let h = HealthTracker::new();
        assert_eq!(h.mode(), DegradedMode::Full);
        assert_eq!(h.note_dispatch(), DegradedMode::Full);
        h.escalate(DegradedMode::ReducedLanes, 10.0);
        assert_eq!(h.note_dispatch(), DegradedMode::ReducedLanes);
        // Monotone: asking for a lower rung is not a recovery.
        h.escalate(DegradedMode::Full, 11.0);
        assert_eq!(h.mode(), DegradedMode::ReducedLanes);
        h.escalate(DegradedMode::Sequential, 12.0);
        assert_eq!(h.note_dispatch(), DegradedMode::Sequential);
        h.recover(25.0);
        assert_eq!(h.mode(), DegradedMode::Full);
        // One degraded window, 10 -> 25 virtual ms.
        let snap = h.snapshot();
        assert_eq!(
            snap.get("recovery_ms")
                .and_then(|r| r.get("count"))
                .and_then(Json::as_f64),
            Some(1.0)
        );
        assert_eq!(
            snap.get("recovery_ms")
                .and_then(|r| r.get("max_ms"))
                .and_then(Json::as_f64),
            Some(15.0)
        );
        assert_eq!(
            snap.get("mode")
                .and_then(|m| m.get("dwell"))
                .and_then(|d| d.get("reduced_lanes"))
                .and_then(Json::as_f64),
            Some(1.0)
        );
        // Recovering while healthy is a no-op.
        h.recover(30.0);
        assert_eq!(
            h.snapshot()
                .get("recovery_ms")
                .and_then(|r| r.get("count"))
                .and_then(Json::as_f64),
            Some(1.0)
        );
    }

    #[test]
    fn slow_lane_detector_marks_persistent_stragglers_only() {
        let h = HealthTracker::new();
        // Balanced lanes: warmup plus plenty of observations, no
        // marks.
        for _ in 0..32 {
            h.observe_lanes(&[100, 100, 100, 100]);
        }
        assert_eq!(h.totals().slow_lane_marks, 0);
        // Lane 3 collapses to ~zero share: once the EWMA crosses
        // half the fair share it earns marks every dispatch.
        for _ in 0..32 {
            h.observe_lanes(&[100, 100, 100, 0]);
        }
        let marks = h.totals().slow_lane_marks;
        assert!(marks > 0, "a collapsed lane must be marked slow");
        // Zero-total and empty observations are ignored.
        h.observe_lanes(&[0, 0, 0, 0]);
        h.observe_lanes(&[]);
        assert_eq!(h.totals().slow_lane_marks, marks);
    }

    #[test]
    fn merge_folds_counters_digests_and_modes() {
        let a = HealthTracker::new();
        let b = HealthTracker::new();
        a.note_served(10);
        a.note_shed(2);
        a.note_injected(FaultKind::LaneStall);
        b.note_served(5);
        b.note_retried(3);
        b.note_injected(FaultKind::LaneStall);
        b.note_injected(FaultKind::QueueSpike);
        b.escalate(DegradedMode::Sequential, 0.0);
        a.merge_from(&b);
        let t = a.totals();
        assert_eq!(t.served_ok, 15);
        assert_eq!(t.shed, 2);
        assert_eq!(t.retried, 3);
        assert_eq!(t.injected_total, 3);
        assert_eq!(a.mode(), DegradedMode::Sequential);
        let snap = a.snapshot();
        assert_eq!(
            snap.get("injected")
                .and_then(|i| i.get("lane_stall"))
                .and_then(Json::as_f64),
            Some(2.0)
        );
    }

    #[test]
    fn compare_flags_degraded_snapshots_and_schema_mismatch() {
        let base = HealthTracker::new();
        base.note_served(100);
        base.note_shed(1);
        for _ in 0..4 {
            base.note_dispatch();
        }
        let cur = HealthTracker::new();
        cur.note_served(40);
        cur.note_shed(60);
        cur.escalate(DegradedMode::Sequential, 0.0);
        for _ in 0..4 {
            cur.note_dispatch();
        }
        cur.recover(500.0);
        cur.escalate(DegradedMode::ReducedLanes, 600.0);
        cur.recover(1100.0);
        let th = HealthThresholds::default();
        let clean =
            compare_health(&base.snapshot(), &base.snapshot(), &th);
        assert!(clean.is_clean(), "{clean}");
        let report = compare_health(&base.snapshot(), &cur.snapshot(), &th);
        assert!(!report.is_clean());
        let invariants: Vec<&str> =
            report.findings.iter().map(|f| f.invariant).collect();
        assert!(invariants.contains(&"shed-rate"), "{invariants:?}");
        assert!(invariants.contains(&"degraded-dwell"), "{invariants:?}");
        assert!(invariants.contains(&"recovery-p95"), "{invariants:?}");
        // Schema mismatch short-circuits the comparison.
        let bogus =
            crate::util::json::parse("{\"schema\": \"nope.v0\"}").unwrap();
        let r = compare_health(&bogus, &base.snapshot(), &th);
        assert!(!r.is_clean());
        assert!(r.findings.iter().all(|f| f.invariant == "health-schema"));
    }
}
