//! The seeded chaos sweep — a *replayable* fault-matrix experiment
//! over a live mini-fleet.
//!
//! [`run`] builds a small sharded deployment (one pinned
//! [`ServeEngine`] per shard over one shared registry), schedules
//! faults on a virtual step clock, and drives deterministic traffic
//! through the outage. Scenario 0 is a scripted ladder walk that
//! exercises every rung (stall → reduced lanes, double stall →
//! sequential fallback, panic containment, outage + failover, queue
//! spike + bounded retry, corrupt-payload admission); scenarios 1..
//! replay seeded [`FaultPlan`]s. Two invariant families are asserted
//! throughout:
//!
//! * **No lost, no duplicated requests** — every submitted request
//!   ends in exactly one counted terminal outcome
//!   (`served_ok + shed + rejected == submitted`, per scenario).
//! * **Bitwise-correct outputs** — every served output equals the
//!   matrix's healthy reference bit for bit (the pooled plan
//!   reference for normal serves, the `Csr::spmv` reference for
//!   sequential-fallback serves — each path is individually
//!   deterministic).
//!
//! Determinism contract: the driver's decisions depend only on the
//! seed and the step counter (the virtual clock `now_ms = step`), and
//! the fleet health document is merged from the *driver's* scenario
//! ledgers — engine-internal trackers are fed by wall-clock busy
//! tallies and stay out of the snapshot — so the same seed produces
//! byte-identical [`ChaosOutcome::health`] across runs.
//!
//! Injected worker panics print the standard panic line to stderr
//! (the hook runs before containment); that noise is the evidence
//! that a real unwind crossed the pool and was survived.

use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::Duration;

use crate::check::{CheckReport, Finding};
use crate::corpus::suite::SuiteSpec;
use crate::service::{
    MatrixRegistry, PlacementPolicy, PlanConfig, Planner, ServeEngine,
    ShardPlacement,
};
use crate::util::json::Json;
use crate::util::rng::Pcg32;

use super::health::{DegradedMode, HealthTracker};
use super::{decorrelated_jitter, FaultEvent, FaultKind, FaultPlan,
    FaultPlanConfig};

/// Per-shard model queue capacity (requests).
const QUEUE_CAP: usize = 8;
/// Requests drained per shard per virtual step.
const DRAIN_PER_STEP: usize = 2;
/// Deadline: queued requests older than this many steps are shed.
const DEADLINE_STEPS: u64 = 6;
/// Admissions attempted by one queue-pressure spike.
const SPIKE_BURST: usize = 30;
/// Worker lanes per shard pool.
const LANES: usize = 4;

/// Chaos sweep parameters.
#[derive(Clone, Copy, Debug)]
pub struct ChaosConfig {
    /// Root seed; scenario `i` derives its fault plan from it.
    pub seed: u64,
    /// Scenarios to run (scenario 0 is always the scripted ladder
    /// walk; the rest replay seeded fault plans).
    pub scenarios: usize,
    /// Virtual steps per scenario — one background request per step.
    pub requests: usize,
    /// Matrices registered from the tiny synthetic suite.
    pub matrices: usize,
    /// Shards (one pinned engine + one model queue each).
    pub shards: usize,
    /// Faults per generated scenario.
    pub faults: usize,
    /// Bounded re-admission budget per overloaded request.
    pub retry_budget: usize,
    /// Deliberately drop one shed from the ledger (scenario 0) — the
    /// planted fault-handling bug the CI smoke proves the sweep
    /// catches.
    pub canary: bool,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            seed: 0xC4A05,
            scenarios: 6,
            requests: 160,
            matrices: 4,
            shards: 3,
            faults: 5,
            retry_budget: 3,
            canary: false,
        }
    }
}

/// What a sweep produced: the findings report, the merged
/// `ft2000.health.v1` document of the driver ledgers, and the ledger
/// denominators.
pub struct ChaosOutcome {
    pub report: CheckReport,
    pub health: Json,
    pub scenarios: usize,
    pub submitted: u64,
}

/// One queued model request: a matrix (by suite position) and its
/// enqueue step (deadline accounting).
struct Pending {
    matrix: usize,
    enq: u64,
}

/// One live fault window.
struct Active {
    expire: u64,
    kind: FaultKind,
    shard: usize,
    lane: usize,
}

/// Terminal outcome of one admission attempt in the model router.
enum Admit {
    Queued,
    Shed,
    Rejected,
}

fn check(
    report: &mut CheckReport,
    ok: bool,
    subject: String,
    invariant: &'static str,
    detail: impl FnOnce() -> String,
) {
    report.checked += 1;
    if !ok {
        report.findings.push(Finding {
            subject,
            invariant,
            detail: detail(),
        });
    }
}

fn bitwise_eq(got: &[f64], want: &[f64]) -> bool {
    got.len() == want.len()
        && got
            .iter()
            .zip(want)
            .all(|(a, b)| a.to_bits() == b.to_bits())
}

fn stalls_on(active: &[Active], shard: usize) -> usize {
    active
        .iter()
        .filter(|a| a.kind == FaultKind::LaneStall && a.shard == shard)
        .count()
}

/// Route + enqueue one request into the model queues: overrides and
/// the down set first, then the capacity check, then a bounded-budget
/// retry scan over the survivors with decorrelated-jitter (virtual)
/// backoff. The caller counts the returned terminal outcome.
#[allow(clippy::too_many_arguments)]
fn admit(
    queues: &mut [VecDeque<Pending>],
    down: &[bool],
    tracker: &HealthTracker,
    rng: &mut Pcg32,
    retry_budget: usize,
    matrix: usize,
    route: usize,
    step: u64,
) -> Admit {
    let shards = queues.len();
    if down.iter().all(|&d| d) {
        return Admit::Rejected;
    }
    let mut candidate = route % shards;
    if down[candidate] {
        match (1..shards)
            .map(|k| (candidate + k) % shards)
            .find(|&s| !down[s])
        {
            Some(s) => {
                tracker.note_failed_over(1);
                candidate = s;
            }
            None => return Admit::Rejected,
        }
    }
    if queues[candidate].len() < QUEUE_CAP {
        queues[candidate].push_back(Pending { matrix, enq: step });
        return Admit::Queued;
    }
    let mut backoff = 1.0;
    for attempt in 0..retry_budget {
        // Bounded retry budget; the backoff is virtual milliseconds —
        // exercised for determinism, never slept on.
        backoff = decorrelated_jitter(rng, backoff, 1.0, 8.0);
        let next = (candidate + 1 + attempt) % shards;
        if down[next] {
            continue;
        }
        tracker.note_retried(1);
        if queues[next].len() < QUEUE_CAP {
            queues[next].push_back(Pending { matrix, enq: step });
            return Admit::Queued;
        }
    }
    Admit::Shed
}

/// The scripted ladder walk of scenario 0 — every fault kind once,
/// ordered so each graceful-degradation mechanism is exercised and
/// recovered inside the step budget. Lane targets use the
/// [`FaultEvent`] encoding (`shard = target % shards`,
/// `lane = 1 + target / shards`).
fn scripted_events(shards: usize) -> Vec<FaultEvent> {
    let s1 = 1 % shards;
    let s2 = 2 % shards;
    vec![
        // Shard 0 lane 1 stalls: ladder -> ReducedLanes.
        FaultEvent {
            step: 2,
            kind: FaultKind::LaneStall,
            target: 0,
            duration: 6,
        },
        // Shard 0 lane 2 turns straggler: EWMA marks, no escalation.
        FaultEvent {
            step: 3,
            kind: FaultKind::LaneSlow,
            target: shards,
            duration: 2,
        },
        // Second stall on shard 0: ladder -> Sequential fallback.
        FaultEvent {
            step: 4,
            kind: FaultKind::LaneStall,
            target: shards,
            duration: 4,
        },
        // A slot closure panics mid-dispatch on shard s1.
        FaultEvent {
            step: 6,
            kind: FaultKind::WorkerPanic,
            target: s1,
            duration: 1,
        },
        // Shard s1 goes dark: failover re-homes its matrices.
        FaultEvent {
            step: 10,
            kind: FaultKind::ShardOutage,
            target: s1,
            duration: 5,
        },
        // Shard s2 blinks.
        FaultEvent {
            step: 14,
            kind: FaultKind::ShardFlap,
            target: s2,
            duration: 1,
        },
        // Queue-pressure burst far past total capacity: bounded
        // retries spill to the other shards, the excess is shed.
        FaultEvent {
            step: 16,
            kind: FaultKind::QueueSpike,
            target: 0,
            duration: 1,
        },
        // Malformed payloads reach admission: counted rejections.
        FaultEvent {
            step: 18,
            kind: FaultKind::CorruptPayload,
            target: 0,
            duration: 1,
        },
    ]
}

/// Run one scenario; returns the number of submitted requests.
fn run_scenario(
    cfg: &ChaosConfig,
    scen: usize,
    report: &mut CheckReport,
    fleet: &HealthTracker,
) -> u64 {
    let shards = cfg.shards.max(1);
    let steps = (cfg.requests as u64).max(24);
    let subj = format!("chaos scenario {scen} (seed {:#x})", cfg.seed);

    // One shared registry of tiny suite matrices, one pinned engine
    // per shard (4 modeled cores each), latch timeouts armed so even
    // a wedged join would surface as a counter, not a hang.
    let mut reg = MatrixRegistry::new();
    let ids = reg.register_suite(&SuiteSpec::tiny(), Some(cfg.matrices.max(1)));
    let registry = Arc::new(reg);
    let engines: Vec<ServeEngine> = (0..shards)
        .map(|s| {
            let e = ServeEngine::shared_pinned(
                registry.clone(),
                Planner::Heuristic,
                PlanConfig::default(),
                (LANES * s, LANES * s + LANES),
            );
            if let Some(pool) = e.pool() {
                pool.set_latch_timeout(Some(Duration::from_millis(250)));
            }
            e
        })
        .collect();
    let nm = ids.len();
    let weights: Vec<f64> = ids
        .iter()
        .map(|&id| registry.entry(id).csr.nnz() as f64)
        .collect();
    let placement = ShardPlacement::build(
        &ids,
        &weights,
        shards,
        PlacementPolicy::HotReplicate { hot: 1 },
    );

    // Deterministic inputs and the two bitwise references per matrix:
    // the sequential `Csr::spmv` output, and each engine's own healthy
    // pooled output (identical plan => identical bits thereafter).
    let xs: Vec<Vec<f64>> = ids
        .iter()
        .map(|&id| {
            (0..registry.entry(id).csr.n_cols)
                .map(|i| ((i % 7) as f64) * 0.25 - 0.5)
                .collect()
        })
        .collect();
    let refs_seq: Vec<Vec<f64>> = ids
        .iter()
        .enumerate()
        .map(|(m, &id)| {
            let csr = &registry.entry(id).csr;
            let mut y = vec![0.0; csr.n_rows];
            csr.spmv(&xs[m], &mut y);
            y
        })
        .collect();
    let mut refs_plan: Vec<Vec<Vec<f64>>> = Vec::with_capacity(shards);
    for e in &engines {
        let mut per_matrix = Vec::with_capacity(nm);
        for (m, &id) in ids.iter().enumerate() {
            match e.execute_batch(id, &[xs[m].as_slice()]) {
                Ok(out) => per_matrix.push(out.ys.into_iter().next()
                    .unwrap_or_default()),
                Err(err) => {
                    check(
                        report,
                        false,
                        subj.clone(),
                        "serve-error",
                        || format!("healthy warmup failed: {err}"),
                    );
                    per_matrix.push(refs_seq[m].clone());
                }
            }
        }
        refs_plan.push(per_matrix);
    }

    // Scenario state: the driver's ledger, model queues, fault plan.
    let tracker = HealthTracker::new();
    let sseed = cfg
        .seed
        .wrapping_add((scen as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let mut rng = Pcg32::new(sseed ^ 0x1C4A);
    let events = if scen == 0 {
        scripted_events(shards)
    } else {
        let plan_cfg = FaultPlanConfig {
            steps,
            faults: cfg.faults,
            lanes: LANES,
            shards,
        };
        FaultPlan::generate(sseed, &plan_cfg).events().to_vec()
    };
    let mut queues: Vec<VecDeque<Pending>> =
        (0..shards).map(|_| VecDeque::new()).collect();
    let mut down = vec![false; shards];
    let mut overrides: HashMap<usize, usize> = HashMap::new();
    let mut active: Vec<Active> = Vec::new();
    let mut ev_idx = 0usize;
    let mut submitted = 0u64;
    let mut applied = 0u64;
    let mut canary_skips: u64 = if cfg.canary && scen == 0 { 1 } else { 0 };

    // One serve of the queue head, with the bitwise-output check
    // against the reference matching the engine's current rung.
    macro_rules! serve_one {
        ($s:expr, $m:expr) => {{
            let s: usize = $s;
            let m: usize = $m;
            let _ = tracker.note_dispatch();
            let sequential =
                engines[s].health().mode() == DegradedMode::Sequential;
            match engines[s].execute_batch(ids[m], &[xs[m].as_slice()]) {
                Ok(out) => {
                    let want = if sequential {
                        &refs_seq[m]
                    } else {
                        &refs_plan[s][m]
                    };
                    check(
                        report,
                        bitwise_eq(&out.ys[0], want),
                        subj.clone(),
                        "bitwise-output",
                        || {
                            format!(
                                "matrix {m} on shard {s} diverged from its \
                                 healthy reference (sequential={sequential})"
                            )
                        },
                    );
                    tracker.note_served(1);
                    if stalls_on(&active, s) > 0 {
                        tracker.note_degraded_dispatch();
                    }
                }
                Err(err) => check(
                    report,
                    false,
                    subj.clone(),
                    "serve-error",
                    || format!("matrix {m} on shard {s} errored: {err}"),
                ),
            }
        }};
    }

    let mut step: u64 = 0;
    let mut extra: u64 = 0;
    loop {
        let injecting = step < steps;
        let now_ms = step as f64;

        // 1. Expire fault windows due at this step (or everything,
        // once the injection horizon is past).
        let mut expired: Vec<Active> = Vec::new();
        let mut i = 0;
        while i < active.len() {
            if active[i].expire <= step || !injecting {
                expired.push(active.swap_remove(i));
            } else {
                i += 1;
            }
        }
        for a in expired {
            match a.kind {
                FaultKind::LaneStall => {
                    if let Some(pool) = engines[a.shard].pool() {
                        pool.set_lane_stalled(a.lane, false);
                    }
                    if stalls_on(&active, a.shard) == 0 {
                        engines[a.shard].health().recover(now_ms);
                    }
                }
                FaultKind::ShardOutage | FaultKind::ShardFlap => {
                    down[a.shard] = false;
                    let s = a.shard;
                    overrides.retain(|id, _| {
                        placement.home(*id) != Some(s)
                    });
                }
                _ => {}
            }
        }
        if active.is_empty() {
            tracker.recover(now_ms);
        }

        // 2. Apply fault events due at this step.
        while injecting
            && ev_idx < events.len()
            && events[ev_idx].step == step
        {
            let e = events[ev_idx];
            ev_idx += 1;
            applied += 1;
            tracker.note_injected(e.kind);
            match e.kind {
                FaultKind::LaneStall => {
                    let s = e.target % shards;
                    let lane = 1 + e.target / shards;
                    if let Some(pool) = engines[s].pool() {
                        pool.set_lane_stalled(lane, true);
                    }
                    active.push(Active {
                        expire: step + e.duration,
                        kind: e.kind,
                        shard: s,
                        lane,
                    });
                    let to = if stalls_on(&active, s) >= 2 {
                        DegradedMode::Sequential
                    } else {
                        DegradedMode::ReducedLanes
                    };
                    tracker.escalate(to, now_ms);
                    engines[s].health().escalate(to, now_ms);
                    if to == DegradedMode::Sequential {
                        // Prove the last rung end to end: serve one
                        // request through the wedged shard right now
                        // and require the sequential-fallback counter
                        // to move.
                        let before = engines[s]
                            .health()
                            .totals()
                            .sequential_dispatches;
                        let m = (step as usize) % nm;
                        submitted += 1;
                        serve_one!(s, m);
                        let after = engines[s]
                            .health()
                            .totals()
                            .sequential_dispatches;
                        check(
                            report,
                            after > before,
                            subj.clone(),
                            "sequential-fallback",
                            || format!(
                                "shard {s} served in Sequential mode but \
                                 the fallback counter did not move"
                            ),
                        );
                    }
                }
                FaultKind::LaneSlow => {
                    let lane = (1 + e.target / shards).min(LANES);
                    // Synthetic straggler: feed the EWMA detector a
                    // deterministic collapsed share for this lane.
                    let mut busy = [100u64; LANES + 1];
                    busy[lane] = 4;
                    for _ in 0..12 {
                        tracker.observe_lanes(&busy);
                    }
                }
                FaultKind::WorkerPanic => {
                    let s = e.target % shards;
                    if let Some(pool) = engines[s].pool() {
                        let contained = catch_unwind(AssertUnwindSafe(|| {
                            pool.run(2, &|_| {
                                panic!("chaos: injected worker panic")
                            });
                        }))
                        .is_err();
                        check(
                            report,
                            contained,
                            subj.clone(),
                            "panic-contained",
                            || format!(
                                "shard {s}: injected slot panic did not \
                                 propagate to the dispatcher"
                            ),
                        );
                        if contained {
                            tracker.note_panic_contained();
                        }
                    }
                }
                FaultKind::ShardOutage | FaultKind::ShardFlap => {
                    let s = e.target % shards;
                    if !down[s] {
                        down[s] = true;
                        let alive: Vec<usize> = (0..shards)
                            .filter(|&k| !down[k])
                            .collect();
                        let plan = placement.reassign_plan(s, &alive);
                        tracker.note_failed_over(plan.len() as u64);
                        for (id, to) in plan {
                            overrides.insert(id, to);
                        }
                        tracker.escalate(DegradedMode::ReducedLanes, now_ms);
                        active.push(Active {
                            expire: step + e.duration,
                            kind: e.kind,
                            shard: s,
                            lane: 0,
                        });
                        // Re-admit the dark shard's backlog onto the
                        // survivors under the bounded retry budget.
                        let backlog: Vec<Pending> =
                            queues[s].drain(..).collect();
                        for p in backlog {
                            let route = overrides
                                .get(&ids[p.matrix])
                                .copied()
                                .unwrap_or(s);
                            match admit(
                                &mut queues,
                                &down,
                                &tracker,
                                &mut rng,
                                cfg.retry_budget,
                                p.matrix,
                                route,
                                p.enq,
                            ) {
                                Admit::Queued => {}
                                Admit::Shed => {
                                    if canary_skips > 0 {
                                        canary_skips -= 1;
                                    } else {
                                        tracker.note_shed(1);
                                    }
                                }
                                Admit::Rejected => tracker.note_rejected(1),
                            }
                        }
                    }
                }
                FaultKind::QueueSpike => {
                    let s = e.target % shards;
                    for _ in 0..SPIKE_BURST {
                        submitted += 1;
                        match admit(
                            &mut queues,
                            &down,
                            &tracker,
                            &mut rng,
                            cfg.retry_budget,
                            0,
                            s,
                            step,
                        ) {
                            Admit::Queued => {}
                            Admit::Shed => {
                                if canary_skips > 0 {
                                    canary_skips -= 1;
                                } else {
                                    tracker.note_shed(1);
                                }
                            }
                            Admit::Rejected => tracker.note_rejected(1),
                        }
                    }
                }
                FaultKind::CorruptPayload => {
                    // Both corruption shapes through the admission
                    // verifier on a scratch registry: a structurally
                    // corrupt CSR and an unparseable payload. Each
                    // must be a counted rejection, never a panic.
                    let mut scratch_reg = MatrixRegistry::new();
                    let mut bad = registry.entry(ids[0]).csr.clone();
                    bad.indices[0] = bad.n_cols as u32;
                    let structural =
                        scratch_reg.try_register("chaos-oob", bad).is_err();
                    let nan_mtx = "%%MatrixMarket matrix coordinate real \
                                   general\n2 2 1\n1 1 NaN\n";
                    let parse = scratch_reg
                        .register_mtx_reader("chaos-nan", nan_mtx.as_bytes())
                        .is_err();
                    check(
                        report,
                        structural && parse && scratch_reg.rejected() == 2,
                        subj.clone(),
                        "corrupt-admission",
                        || format!(
                            "corrupt payloads must be counted rejections: \
                             structural={structural} parse={parse} \
                             rejected={}",
                            scratch_reg.rejected()
                        ),
                    );
                    tracker.note_rejected_corrupt(2);
                }
            }
        }

        // 3. Background traffic: one request per injection step.
        if injecting {
            let m = (step as usize) % nm;
            let route = overrides
                .get(&ids[m])
                .copied()
                .or_else(|| placement.home(ids[m]))
                .unwrap_or((step as usize) % shards);
            submitted += 1;
            match admit(
                &mut queues,
                &down,
                &tracker,
                &mut rng,
                cfg.retry_budget,
                m,
                route,
                step,
            ) {
                Admit::Queued => {}
                Admit::Shed => {
                    if canary_skips > 0 {
                        canary_skips -= 1;
                    } else {
                        tracker.note_shed(1);
                    }
                }
                Admit::Rejected => tracker.note_rejected(1),
            }
        }

        // 4. Drain: up to DRAIN_PER_STEP per live shard, shedding
        // anything past its deadline.
        for s in 0..shards {
            if down[s] {
                continue;
            }
            for _ in 0..DRAIN_PER_STEP {
                let Some(p) = queues[s].pop_front() else { break };
                if step.saturating_sub(p.enq) > DEADLINE_STEPS {
                    if canary_skips > 0 {
                        canary_skips -= 1;
                    } else {
                        tracker.note_shed(1);
                    }
                    continue;
                }
                serve_one!(s, p.matrix);
            }
        }

        step += 1;
        if step >= steps {
            extra += 1;
            let drained = queues.iter().all(VecDeque::is_empty);
            if (drained && active.is_empty()) || extra > 10_000 {
                break;
            }
        }
    }

    // Scenario-end invariants.
    check(
        report,
        queues.iter().all(VecDeque::is_empty),
        subj.clone(),
        "drain-complete",
        || "model queues still hold requests after the drain".to_string(),
    );
    let t = tracker.totals();
    check(
        report,
        t.served_ok + t.shed + t.rejected == submitted,
        subj.clone(),
        "request-ledger",
        || {
            format!(
                "served {} + shed {} + rejected {} != submitted {submitted} \
                 — a request was lost or double-counted",
                t.served_ok, t.shed, t.rejected
            )
        },
    );
    check(
        report,
        t.injected_total == applied,
        subj.clone(),
        "fault-accounting",
        || {
            format!(
                "{} faults applied but {} recorded as injected",
                applied, t.injected_total
            )
        },
    );
    check(
        report,
        tracker.mode() == DegradedMode::Full
            && engines
                .iter()
                .all(|e| e.health().mode() == DegradedMode::Full),
        subj.clone(),
        "mode-recovered",
        || "a ladder did not return to Full after all faults expired"
            .to_string(),
    );
    for (s, e) in engines.iter().enumerate() {
        if let Some(pool) = e.pool() {
            let hits = crate::util::ordatomic::OrdAtomicUsize::named(
                0,
                "chaos.survive",
            );
            pool.run(2, &|_| {
                // ord: Relaxed RMW — independent tally, no ordering
                // needed; the pool latch is the synchronization.
                hits.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            });
            check(
                report,
                hits.into_inner() == 2,
                subj.clone(),
                "pool-survives",
                || format!(
                    "shard {s} pool failed a post-scenario dispatch"
                ),
            );
        }
    }

    fleet.merge_from(&tracker);
    submitted
}

/// Run the sweep: the scripted ladder walk plus
/// `cfg.scenarios - 1` seeded fault-plan replays, merging every
/// scenario's driver ledger into one fleet health document.
pub fn run(cfg: &ChaosConfig) -> ChaosOutcome {
    let mut report = CheckReport::new();
    let fleet = HealthTracker::new();
    let scenarios = cfg.scenarios.max(1);
    let mut submitted = 0u64;
    for scen in 0..scenarios {
        submitted += run_scenario(cfg, scen, &mut report, &fleet);
    }
    ChaosOutcome {
        report,
        health: fleet.snapshot(),
        scenarios,
        submitted,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> ChaosConfig {
        ChaosConfig {
            scenarios: 2,
            requests: 32,
            matrices: 3,
            shards: 2,
            faults: 3,
            ..ChaosConfig::default()
        }
    }

    #[test]
    fn sweep_is_clean_and_replays_bit_identically() {
        if cfg!(miri) {
            return; // real pools + panics; far too slow under miri
        }
        let cfg = small();
        let a = run(&cfg);
        assert!(a.report.is_clean(), "{}", a.report);
        assert!(a.submitted > 0);
        assert_eq!(a.scenarios, 2);
        let b = run(&cfg);
        assert!(b.report.is_clean(), "{}", b.report);
        assert_eq!(
            a.health.to_string(),
            b.health.to_string(),
            "same seed must replay to a byte-identical health document"
        );
        assert_eq!(a.submitted, b.submitted);
        // A different seed is a different experiment.
        let c = run(&ChaosConfig { seed: 0xC4A06, ..cfg });
        assert!(c.report.is_clean(), "{}", c.report);
    }

    #[test]
    fn canary_ledger_bug_is_caught() {
        if cfg!(miri) {
            return;
        }
        let out = run(&ChaosConfig {
            canary: true,
            scenarios: 1,
            requests: 32,
            matrices: 3,
            shards: 2,
            ..ChaosConfig::default()
        });
        assert!(
            !out.report.is_clean(),
            "a dropped shed must break the request ledger"
        );
        assert!(
            out.report
                .findings
                .iter()
                .any(|f| f.invariant == "request-ledger"),
            "{}",
            out.report
        );
    }
}
