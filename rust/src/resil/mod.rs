//! Deterministic fault injection and graceful degradation.
//!
//! The paper's scalability story is really a fragility story: SpMV
//! speedup on FT-2000+ survives only while every lane pulls its
//! weight and every panel answers — one straggler core or one
//! saturated queue and the speedup curve folds. A serving fleet
//! built on that observation has to treat failure as a first-class
//! *input*, not an exception: this module makes it one, the same way
//! `check` made structure checkable and `check::hb` made ordering
//! checkable.
//!
//! Three planes:
//!
//! * **Injection** — a seeded [`FaultPlan`] schedules faults on a
//!   virtual clock (steps, not wall time), so a chaos replay is
//!   bit-reproducible per seed like `check::interleave`. The fault
//!   taxonomy ([`FaultKind`]) covers worker-lane stalls and
//!   slowdowns (straggler emulation through
//!   [`crate::exec::ExecPool::set_lane_stalled`]), worker panics,
//!   shard outages and flapping, queue-pressure spikes, and
//!   corrupt-payload admissions (routed through the registry
//!   verifier).
//! * **Degradation** — [`health::HealthTracker`] keeps per-lane EWMA
//!   slow-lane detection fed by the busy-tally probe, a
//!   [`health::DegradedMode`] ladder (full pool → reduced lanes →
//!   sequential fallback) that the serve path consults on every
//!   dispatch and autotune treats as temporary suppression, bounded
//!   retry budgets with [`decorrelated_jitter`] backoff, and shard
//!   failover that re-homes a dead shard's matrices onto survivors
//!   (see `service::shard`).
//! * **Evidence** — every injected fault and every recovery decision
//!   is a counted outcome in a versioned `ft2000.health.v1` snapshot
//!   (merged across shards like `ft2000.scaling.v1`); [`chaos::run`]
//!   sweeps a seeded fault matrix asserting no-lost-no-duplicated
//!   requests and bitwise-correct outputs, and
//!   [`health::compare_health`] turns two snapshots into counted
//!   regression findings for `obs-report`.

pub mod chaos;
pub mod health;

pub use chaos::{ChaosConfig, ChaosOutcome};
pub use health::{
    compare_health, DegradedMode, HealthThresholds, HealthTracker,
    HEALTH_SCHEMA,
};

use crate::util::rng::Pcg32;

/// The fault taxonomy. Every kind is non-fatal by contract: the
/// engine must end each one in a counted graceful outcome (degraded,
/// shed, retried, failed-over, rejected) — never a hang, never a
/// wrong answer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultKind {
    /// A worker lane stops claiming slots (hung core emulation).
    LaneStall,
    /// A worker lane runs far under its fair busy share (straggler).
    LaneSlow,
    /// A slot closure panics mid-dispatch.
    WorkerPanic,
    /// A whole shard goes dark for a while.
    ShardOutage,
    /// A shard blinks: a short outage followed by a quick return.
    ShardFlap,
    /// A burst of admissions far past the queue capacity.
    QueueSpike,
    /// A malformed matrix payload reaches admission.
    CorruptPayload,
}

impl FaultKind {
    /// Every kind, in a fixed canonical order (snapshot key order).
    pub const ALL: [FaultKind; 7] = [
        FaultKind::LaneStall,
        FaultKind::LaneSlow,
        FaultKind::WorkerPanic,
        FaultKind::ShardOutage,
        FaultKind::ShardFlap,
        FaultKind::QueueSpike,
        FaultKind::CorruptPayload,
    ];

    /// Stable snake_case name (snapshot keys, tables).
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::LaneStall => "lane_stall",
            FaultKind::LaneSlow => "lane_slow",
            FaultKind::WorkerPanic => "worker_panic",
            FaultKind::ShardOutage => "shard_outage",
            FaultKind::ShardFlap => "shard_flap",
            FaultKind::QueueSpike => "queue_spike",
            FaultKind::CorruptPayload => "corrupt_payload",
        }
    }

    /// Index into [`FaultKind::ALL`] (counter arrays, sort keys).
    pub fn index(&self) -> usize {
        match self {
            FaultKind::LaneStall => 0,
            FaultKind::LaneSlow => 1,
            FaultKind::WorkerPanic => 2,
            FaultKind::ShardOutage => 3,
            FaultKind::ShardFlap => 4,
            FaultKind::QueueSpike => 5,
            FaultKind::CorruptPayload => 6,
        }
    }
}

/// One scheduled fault: fire at virtual step `step`, last `duration`
/// steps. `target` is kind-relative — a lane×shard code for lane
/// faults (`shard = target % shards`, `lane = 1 + target / shards`),
/// a shard index for shard faults, ignored by payload faults.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    pub step: u64,
    pub kind: FaultKind,
    pub target: usize,
    pub duration: u64,
}

/// Shape of a generated fault schedule.
#[derive(Clone, Copy, Debug)]
pub struct FaultPlanConfig {
    /// Virtual steps the scenario runs for.
    pub steps: u64,
    /// How many faults to schedule.
    pub faults: usize,
    /// Worker lanes per shard pool (stall/slow/panic targets).
    pub lanes: usize,
    /// Shards in the fleet (outage/flap/spike targets).
    pub shards: usize,
}

impl Default for FaultPlanConfig {
    fn default() -> Self {
        FaultPlanConfig { steps: 64, faults: 5, lanes: 4, shards: 3 }
    }
}

/// A seeded, virtual-clock fault schedule. Same seed + same config ⇒
/// the identical event list, which is what makes a chaos sweep a
/// *replay* rather than a dice roll.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    seed: u64,
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Generate the schedule for `seed`. Events land in the first
    /// ~three quarters of the step budget so expiry and recovery fit
    /// inside the scenario, and are sorted by
    /// `(step, kind index, target)` so application order is total.
    pub fn generate(seed: u64, cfg: &FaultPlanConfig) -> FaultPlan {
        let mut rng = Pcg32::new(seed ^ 0xFA_017);
        let horizon = (cfg.steps.max(4) * 3 / 4) as usize;
        let mut events = Vec::with_capacity(cfg.faults);
        for _ in 0..cfg.faults {
            let kind = FaultKind::ALL[rng.gen_range(FaultKind::ALL.len())];
            let step = 1 + rng.gen_range(horizon) as u64;
            let target = match kind {
                FaultKind::LaneStall
                | FaultKind::LaneSlow
                | FaultKind::WorkerPanic => {
                    rng.gen_range((cfg.lanes * cfg.shards).max(1))
                }
                FaultKind::ShardOutage
                | FaultKind::ShardFlap
                | FaultKind::QueueSpike => rng.gen_range(cfg.shards.max(1)),
                FaultKind::CorruptPayload => 0,
            };
            let duration = match kind {
                FaultKind::ShardFlap => 1,
                _ => 1 + rng.gen_range(5) as u64,
            };
            events.push(FaultEvent { step, kind, target, duration });
        }
        events.sort_by_key(|e| (e.step, e.kind.index(), e.target));
        FaultPlan { seed, events }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }
}

/// Decorrelated-jitter backoff (the AWS architecture-blog variant):
/// `sleep = min(cap, uniform(base, prev * 3))`, never below `base`.
/// On a virtual clock the returned value is a step delay; on a wall
/// clock, milliseconds — either way the sequence is deterministic
/// per RNG state, which keeps retry schedules replayable.
pub fn decorrelated_jitter(
    rng: &mut Pcg32,
    prev_ms: f64,
    base_ms: f64,
    cap_ms: f64,
) -> f64 {
    let base = base_ms.max(0.0);
    let span = (prev_ms * 3.0 - base).max(0.0);
    (base + rng.gen_f64() * span).min(cap_ms.max(base))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_plans_are_bit_reproducible_per_seed() {
        let cfg = FaultPlanConfig::default();
        let a = FaultPlan::generate(0xC4A05, &cfg);
        let b = FaultPlan::generate(0xC4A05, &cfg);
        assert_eq!(a.events(), b.events());
        assert_eq!(a.seed(), 0xC4A05);
        assert_eq!(a.events().len(), cfg.faults);
        let c = FaultPlan::generate(0xC4A06, &cfg);
        assert_ne!(
            a.events(),
            c.events(),
            "different seeds must draw different schedules"
        );
        // Sorted by (step, kind, target); every event fits the run
        // with room for its expiry.
        for w in a.events().windows(2) {
            assert!(
                (w[0].step, w[0].kind.index(), w[0].target)
                    <= (w[1].step, w[1].kind.index(), w[1].target)
            );
        }
        for e in a.events() {
            assert!(e.step >= 1 && e.step <= cfg.steps * 3 / 4);
            assert!(e.duration >= 1 && e.duration <= 6);
        }
    }

    #[test]
    fn fault_kind_names_and_indices_are_stable() {
        for (i, k) in FaultKind::ALL.iter().enumerate() {
            assert_eq!(k.index(), i);
        }
        let names: Vec<&str> =
            FaultKind::ALL.iter().map(FaultKind::name).collect();
        assert_eq!(
            names,
            vec![
                "lane_stall",
                "lane_slow",
                "worker_panic",
                "shard_outage",
                "shard_flap",
                "queue_spike",
                "corrupt_payload",
            ]
        );
    }

    #[test]
    fn jitter_is_bounded_and_deterministic() {
        let mut rng = Pcg32::new(7);
        let mut prev = 1.0;
        let mut seq = Vec::new();
        for _ in 0..64 {
            prev = decorrelated_jitter(&mut rng, prev, 1.0, 20.0);
            assert!(prev >= 1.0 && prev <= 20.0, "{prev}");
            seq.push(prev);
        }
        let mut rng2 = Pcg32::new(7);
        let mut prev2 = 1.0;
        for &want in &seq {
            prev2 = decorrelated_jitter(&mut rng2, prev2, 1.0, 20.0);
            assert_eq!(prev2.to_bits(), want.to_bits());
        }
        // The cap really binds.
        let mut rng = Pcg32::new(9);
        let v = decorrelated_jitter(&mut rng, 1e9, 1.0, 20.0);
        assert!(v <= 20.0);
    }
}
