//! Hand-rolled CLI (clap is unavailable offline).
//!
//! ```text
//! ft2000-spmv sweep   [--suite tiny|fast|full] [--schedule S] [--placement P] [--threads 1,2,3,4] [--csv PATH]
//! ft2000-spmv train   [--suite tiny|fast|full] [--trees N]
//! ft2000-spmv analyze (--named NAME | --mtx PATH)
//! ft2000-spmv verify  [--artifacts DIR]
//! ft2000-spmv serve-bench [--suite S] [--matrices N] [--batches 1,2,4,8,16] [--workers W]
//! ft2000-spmv replay  [--suite S] [--pattern uniform|zipf|bursty] [--requests N] [--clients C] ...
//! ft2000-spmv check   [--suite S] [--matrices N] [--seed S] [--quick]
//! ft2000-spmv chaos   [--seed S] [--scenarios N] [--canary] [--health-out PATH]
//! ft2000-spmv info
//! ```

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::corpus::suite::SuiteSpec;
use crate::corpus::NamedMatrix;
use crate::sched::Schedule;
use crate::service::PlacementPolicy;
use crate::sim::topology::Placement;

/// Parsed command line.
#[derive(Clone, Debug)]
pub struct Cli {
    pub command: Command,
}

#[derive(Clone, Debug)]
pub enum Command {
    /// Corpus sweep -> Table 2 / Fig 4 summaries (+ optional CSV).
    Sweep {
        suite: SuiteSpec,
        schedule: Schedule,
        placement: Placement,
        threads: Vec<usize>,
        csv: Option<String>,
    },
    /// Train the regression forest and print importances + Fig 5 tree.
    Train { suite: SuiteSpec, trees: usize },
    /// Profile one matrix and print the advisor diagnosis.
    Analyze { source: MatrixSource },
    /// Check PJRT artifacts against the native executor.
    Verify { artifacts: String },
    /// Write a full markdown characterization report for one matrix.
    Report { source: MatrixSource, out: Option<String> },
    /// Export the synthetic corpus as MatrixMarket files.
    Export { suite: SuiteSpec, dir: String },
    /// Batched-serving microbenchmark: SpMM vs repeated SpMV, plus a
    /// live throughput run — sharded (panel-aware) by default, the
    /// legacy global queue with `--shards 1`.
    ServeBench {
        suite: SuiteSpec,
        matrices: usize,
        batches: Vec<usize>,
        workers: usize,
        /// Serving shards (modeled NUMA panels); 1 = legacy global
        /// queue.
        shards: usize,
        /// Per-shard queue capacity (admission control); 0 = unbounded.
        queue_cap: usize,
        policy: PlacementPolicy,
        /// Persistent executor pool (`--pool`, default) vs per-request
        /// scoped threads (`--spawn`).
        pooled: bool,
        /// Plan-cache capacity (entries, LRU); 0 = unbounded.
        plan_cache_cap: usize,
        /// Online plan autotuning from measured wall-clock latency.
        tune: bool,
        /// Write a Chrome trace-event JSON of the serving run here
        /// (enables stage-span tracing).
        trace_out: Option<String>,
        /// Write the unified metrics snapshot JSON here.
        metrics_out: Option<String>,
        /// Write the `ft2000.scaling.v1` snapshot JSON here.
        scaling_out: Option<String>,
    },
    /// Deterministic traffic replay through the serving engine.
    Replay {
        suite: SuiteSpec,
        pattern: TrafficPattern,
        requests: usize,
        matrices: usize,
        max_batch: usize,
        /// 0 = open loop at `rate`; >0 = closed loop with this many
        /// clients.
        clients: usize,
        rate: f64,
        seed: u64,
        planner: PlannerKind,
        json: Option<String>,
        /// >1 replays the stream through that many virtual panels.
        shards: usize,
        /// Virtual admission bound per server; 0 = unbounded.
        queue_cap: usize,
        policy: PlacementPolicy,
        /// Pool-backed kernel execution (`--pool`, default) vs
        /// per-request scoped threads (`--spawn`).
        pooled: bool,
        /// Plan-cache capacity (entries, LRU); 0 = unbounded.
        plan_cache_cap: usize,
        /// Online plan autotuning on the deterministic virtual clock;
        /// the replay prints an autotune report after the serving
        /// report.
        tune: bool,
        tune_policy: TunePolicyKind,
        /// JSON tuning-state path: loaded (warm start) if it exists,
        /// written back after the replay. Single-shard replays only.
        tune_state: Option<String>,
        /// Write a Chrome trace-event JSON of the replay (virtual
        /// timeline) here (enables stage-span tracing).
        trace_out: Option<String>,
        /// Write the unified metrics snapshot JSON here.
        metrics_out: Option<String>,
        /// Write the `ft2000.scaling.v1` snapshot JSON here.
        scaling_out: Option<String>,
        /// Model-only replay (`--model`): skip kernel execution and
        /// replay the deterministic queueing model alone — the mode
        /// the obs-report baseline/current CI gate feeds on, because
        /// two identical model replays are bit-identical.
        model: bool,
    },
    /// Structural check sweep: run the invariant verifier over the
    /// corpus, every plan family, the plan cache, and the
    /// interleaving harness; exit nonzero on any finding.
    Check {
        suite: SuiteSpec,
        matrices: usize,
        /// Seed of the interleaving-harness schedule permutations.
        seed: u64,
        /// Short harness mode for CI smokes.
        quick: bool,
        /// Run the happens-before race detector over the lock-free
        /// core (needs the `hbcheck` build feature).
        hb: bool,
    },
    /// Diff snapshot pairs into counted regression findings and exit
    /// nonzero on any: two `ft2000.scaling.v1` snapshots
    /// (`--baseline/--current`: efficiency drop, knee shift,
    /// stage-share drift, queue-wait SLO burn) and/or two
    /// `ft2000.health.v1` snapshots
    /// (`--health-baseline/--health-current`: recovery-p95 burn,
    /// shed-rate drift, degraded-dwell drift). Each pair is optional
    /// but must come whole; at least one pair is required.
    ObsReport {
        baseline: Option<String>,
        current: Option<String>,
        /// Relative peak-speedup drop tolerance (default 0.10).
        efficiency_drop: f64,
        /// Knee shift (threads) tolerance (default 2).
        knee_shift: usize,
        /// Gap-share drift tolerance (default 0.10).
        share_drift: f64,
        /// Absolute queue-wait p95 SLO in ms; unset derives
        /// `2 * baseline p95 + 1ms`.
        queue_p95_ms: Option<f64>,
        health_baseline: Option<String>,
        health_current: Option<String>,
        /// Absolute recovery-p95 SLO in ms; unset derives
        /// `2 * baseline p95 + 1ms`.
        recovery_p95_ms: Option<f64>,
        /// Absolute shed-rate drift tolerance (default 0.05).
        shed_rate_drift: f64,
        /// Absolute degraded-dwell-fraction drift tolerance
        /// (default 0.10).
        dwell_drift: f64,
    },
    /// Seeded chaos sweep over the serving fleet: replay a fault
    /// matrix (scenario 0 is the scripted ladder walk), assert
    /// no-lost-no-duplicated requests and bitwise-correct outputs,
    /// and emit the merged `ft2000.health.v1` document; exit nonzero
    /// on any finding.
    Chaos {
        seed: u64,
        scenarios: usize,
        requests: usize,
        matrices: usize,
        shards: usize,
        faults: usize,
        retry_budget: usize,
        /// Plant a ledger bug (drop one shed) — the negative control
        /// proving the sweep catches broken fault handling.
        canary: bool,
        /// Write the merged `ft2000.health.v1` snapshot JSON here.
        health_out: Option<String>,
    },
    /// Print topology/provenance info.
    Info,
}

/// Explore/exploit policy of the `--tune` flag.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TunePolicyKind {
    Epsilon,
    Ucb,
}

/// Traffic shape of the `replay` subcommand.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrafficPattern {
    Uniform,
    Zipf,
    Bursty,
}

/// Plan-decision mode of the serving engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlannerKind {
    Heuristic,
    Learned,
}

#[derive(Clone, Debug)]
pub enum MatrixSource {
    Named(NamedMatrix),
    MatrixMarket(String),
}

pub fn usage() -> &'static str {
    "usage: ft2000-spmv <sweep|train|analyze|verify|report|export|serve-bench|replay|check|obs-report|chaos|info> [options]\n\
     \n\
     sweep    --suite tiny|fast|full   corpus scale (default fast)\n\
     \u{20}        --schedule csr|balanced|csr5|dynamic|sell\n\
     \u{20}        --placement group|private\n\
     \u{20}        --threads 1,2,3,4\n\
     \u{20}        --csv PATH           dump per-matrix results\n\
     train    --suite tiny|fast|full  --trees N (default 20)\n\
     analyze  --named bone010|exdata_1|conf5_4-8x8-20|debr|appu|asia_osm\n\
     \u{20}        --mtx PATH           MatrixMarket file\n\
     verify   --artifacts DIR        (default ./artifacts)\n\
     report   --named NAME | --mtx PATH  [--out FILE]\n\
     export   --suite tiny|fast|full --dir PATH\n\
     serve-bench --suite tiny|fast|full --matrices N (default 6)\n\
     \u{20}        --batches 1,2,4,8,16  --workers W (default 2, per shard)\n\
     \u{20}        --shards N (default 8; 1 = legacy global queue)\n\
     \u{20}        --queue-cap N (default 1024; 0 = unbounded)\n\
     \u{20}        --policy home|replicate [--hot N]  matrix placement\n\
     \u{20}        --pool | --spawn     persistent executor pool (default)\n\
     \u{20}                             vs per-request scoped threads\n\
     \u{20}        --plan-cache-cap N (default 0 = unbounded; LRU)\n\
     \u{20}        --tune               online plan autotuning (wall clock)\n\
     \u{20}        --trace-out PATH     Chrome trace JSON (enables tracing)\n\
     \u{20}        --metrics-out PATH   unified metrics snapshot JSON\n\
     \u{20}        --scaling-out PATH   ft2000.scaling.v1 snapshot JSON\n\
     replay   --suite tiny|fast|full   corpus scale (default fast)\n\
     \u{20}        --pattern uniform|zipf|bursty (default zipf)\n\
     \u{20}        --requests N (default 2000)  --matrices N (default 32)\n\
     \u{20}        --max-batch B (default 16)\n\
     \u{20}        --clients C (default 0 = open loop) --rate R (default 4000)\n\
     \u{20}        --seed S  --planner heuristic|learned (default learned)\n\
     \u{20}        --shards N (default 1)  --queue-cap N (default 0)\n\
     \u{20}        --policy home|replicate [--hot N]\n\
     \u{20}        --pool | --spawn     executor dispatch mode (pool default)\n\
     \u{20}        --plan-cache-cap N (default 0 = unbounded; LRU)\n\
     \u{20}        --tune               online plan autotuning + report\n\
     \u{20}        --tune-policy epsilon|ucb (default epsilon)\n\
     \u{20}        --tune-state PATH    JSON warm start / snapshot (1 shard)\n\
     \u{20}        --json PATH          dump the report as JSON\n\
     \u{20}        --trace-out PATH     Chrome trace JSON, virtual timeline\n\
     \u{20}        --metrics-out PATH   unified metrics snapshot JSON\n\
     \u{20}        --scaling-out PATH   ft2000.scaling.v1 snapshot JSON\n\
     \u{20}        --model              queueing model only (no kernels);\n\
     \u{20}                             bit-identical across runs\n\
     check    --suite tiny|fast|full   corpus scale (default tiny)\n\
     \u{20}        --matrices N (default 8)  --seed S\n\
     \u{20}        --quick              short interleaving-harness mode\n\
     \u{20}        --hb                 happens-before race detection over\n\
     \u{20}                             the lock-free core (hbcheck build)\n\
     obs-report --baseline A.json --current B.json  diff two\n\
     \u{20}        ft2000.scaling.v1 snapshots; exit nonzero on findings\n\
     \u{20}        --efficiency-drop F (default 0.10)\n\
     \u{20}        --knee-shift N (default 2)\n\
     \u{20}        --share-drift F (default 0.10)\n\
     \u{20}        --queue-p95-ms MS (default 2*baseline p95 + 1 ms)\n\
     \u{20}        --health-baseline A.json --health-current B.json\n\
     \u{20}                             diff two ft2000.health.v1 snapshots\n\
     \u{20}                             (each pair optional, at least one)\n\
     \u{20}        --recovery-p95-ms MS (default 2*baseline p95 + 1 ms)\n\
     \u{20}        --shed-rate-drift F (default 0.05)\n\
     \u{20}        --dwell-drift F (default 0.10)\n\
     chaos    --seed S (default 0xC4A05)  --scenarios N (default 6)\n\
     \u{20}        --requests N (default 160, per scenario)\n\
     \u{20}        --matrices N (default 4)  --shards N (default 3)\n\
     \u{20}        --faults N (default 5, per generated scenario)\n\
     \u{20}        --retry-budget N (default 3)\n\
     \u{20}        --canary             plant a ledger bug (negative control;\n\
     \u{20}                             the sweep must exit nonzero)\n\
     \u{20}        --health-out PATH    merged ft2000.health.v1 JSON\n\
     info"
}

/// Flags that take no value (presence toggles).
const BOOL_FLAGS: &[&str] =
    &["pool", "spawn", "tune", "quick", "hb", "model", "canary"];

fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            if BOOL_FLAGS.contains(&key) {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
                continue;
            }
            let val = args
                .get(i + 1)
                .ok_or_else(|| anyhow!("flag --{key} needs a value"))?;
            flags.insert(key.to_string(), val.clone());
            i += 2;
        } else {
            bail!("unexpected argument '{a}'");
        }
    }
    Ok(flags)
}

/// Executor dispatch mode: `--pool` (persistent per-shard executor
/// pool, the default) vs `--spawn` (per-request scoped threads, the
/// legacy baseline of the A/B).
fn parse_pooled(flags: &HashMap<String, String>) -> Result<bool> {
    if flags.contains_key("pool") && flags.contains_key("spawn") {
        bail!("--pool and --spawn are mutually exclusive");
    }
    Ok(!flags.contains_key("spawn"))
}

fn parse_suite(flags: &HashMap<String, String>) -> Result<SuiteSpec> {
    match flags.get("suite").map(String::as_str).unwrap_or("fast") {
        "tiny" => Ok(SuiteSpec::tiny()),
        "fast" => Ok(SuiteSpec::fast()),
        "full" => Ok(SuiteSpec::full()),
        other => bail!("unknown suite '{other}' (tiny|fast|full)"),
    }
}

fn parse_schedule(flags: &HashMap<String, String>) -> Result<Schedule> {
    match flags.get("schedule").map(String::as_str).unwrap_or("csr") {
        "csr" => Ok(Schedule::CsrRowStatic),
        "balanced" => Ok(Schedule::CsrRowBalanced),
        "csr5" => Ok(Schedule::Csr5Tiles { tile_nnz: 256 }),
        "dynamic" => Ok(Schedule::CsrDynamic { chunk: 64 }),
        "sell" => Ok(Schedule::SellChunks { c: 8, sigma: 64 }),
        other => bail!("unknown schedule '{other}'"),
    }
}

fn parse_placement(flags: &HashMap<String, String>) -> Result<Placement> {
    match flags.get("placement").map(String::as_str).unwrap_or("group") {
        "group" => Ok(Placement::CoreGroupFirst),
        "private" => Ok(Placement::PrivateL2),
        other => bail!("unknown placement '{other}' (group|private)"),
    }
}

fn parse_threads(flags: &HashMap<String, String>) -> Result<Vec<usize>> {
    let raw = flags
        .get("threads")
        .map(String::as_str)
        .unwrap_or("1,2,3,4");
    let mut out = Vec::new();
    for part in raw.split(',') {
        out.push(
            part.trim()
                .parse::<usize>()
                .map_err(|_| anyhow!("bad thread count '{part}'"))?,
        );
    }
    if out.first() != Some(&1) {
        bail!("--threads must start with 1 (the speedup baseline)");
    }
    Ok(out)
}

fn parse_usize(
    flags: &HashMap<String, String>,
    key: &str,
    default: usize,
) -> Result<usize> {
    flags
        .get(key)
        .map(|s| s.parse())
        .transpose()
        .map_err(|_| anyhow!("bad --{key}"))
        .map(|v| v.unwrap_or(default))
}

fn parse_f64(
    flags: &HashMap<String, String>,
    key: &str,
    default: f64,
) -> Result<f64> {
    flags
        .get(key)
        .map(|s| s.parse())
        .transpose()
        .map_err(|_| anyhow!("bad --{key}"))
        .map(|v| v.unwrap_or(default))
}

fn parse_batches(flags: &HashMap<String, String>) -> Result<Vec<usize>> {
    let raw = flags
        .get("batches")
        .map(String::as_str)
        .unwrap_or("1,2,4,8,16");
    let mut out = Vec::new();
    for part in raw.split(',') {
        let b: usize = part
            .trim()
            .parse()
            .map_err(|_| anyhow!("bad batch size '{part}'"))?;
        if b == 0 {
            bail!("batch sizes must be >= 1");
        }
        out.push(b);
    }
    Ok(out)
}

fn parse_pattern(flags: &HashMap<String, String>) -> Result<TrafficPattern> {
    match flags.get("pattern").map(String::as_str).unwrap_or("zipf") {
        "uniform" => Ok(TrafficPattern::Uniform),
        "zipf" => Ok(TrafficPattern::Zipf),
        "bursty" => Ok(TrafficPattern::Bursty),
        other => bail!("unknown pattern '{other}' (uniform|zipf|bursty)"),
    }
}

fn parse_planner(flags: &HashMap<String, String>) -> Result<PlannerKind> {
    match flags.get("planner").map(String::as_str).unwrap_or("learned") {
        "heuristic" => Ok(PlannerKind::Heuristic),
        "learned" => Ok(PlannerKind::Learned),
        other => bail!("unknown planner '{other}' (heuristic|learned)"),
    }
}

fn parse_policy(
    flags: &HashMap<String, String>,
) -> Result<PlacementPolicy> {
    match flags.get("policy").map(String::as_str).unwrap_or("replicate") {
        "home" => Ok(PlacementPolicy::Home),
        "replicate" => Ok(PlacementPolicy::HotReplicate {
            hot: parse_usize(flags, "hot", 2)?,
        }),
        other => bail!("unknown policy '{other}' (home|replicate)"),
    }
}

fn parse_tune_policy(
    flags: &HashMap<String, String>,
) -> Result<TunePolicyKind> {
    match flags.get("tune-policy").map(String::as_str).unwrap_or("epsilon") {
        "epsilon" => Ok(TunePolicyKind::Epsilon),
        "ucb" => Ok(TunePolicyKind::Ucb),
        other => bail!("unknown tune policy '{other}' (epsilon|ucb)"),
    }
}

fn parse_named(name: &str) -> Result<NamedMatrix> {
    NamedMatrix::ALL
        .into_iter()
        .find(|m| m.name() == name)
        .ok_or_else(|| {
            anyhow!(
                "unknown matrix '{name}' (known: {})",
                NamedMatrix::ALL
                    .iter()
                    .map(|m| m.name())
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })
}

/// Parse `argv[1..]`.
pub fn parse(args: &[String]) -> Result<Cli> {
    let (cmd, rest) = args
        .split_first()
        .ok_or_else(|| anyhow!("missing command\n{}", usage()))?;
    let flags = parse_flags(rest)?;
    let command = match cmd.as_str() {
        "sweep" => Command::Sweep {
            suite: parse_suite(&flags)?,
            schedule: parse_schedule(&flags)?,
            placement: parse_placement(&flags)?,
            threads: parse_threads(&flags)?,
            csv: flags.get("csv").cloned(),
        },
        "train" => Command::Train {
            suite: parse_suite(&flags)?,
            trees: flags
                .get("trees")
                .map(|s| s.parse())
                .transpose()
                .map_err(|_| anyhow!("bad --trees"))?
                .unwrap_or(20),
        },
        "analyze" => {
            let source = if let Some(n) = flags.get("named") {
                MatrixSource::Named(parse_named(n)?)
            } else if let Some(p) = flags.get("mtx") {
                MatrixSource::MatrixMarket(p.clone())
            } else {
                bail!("analyze needs --named NAME or --mtx PATH");
            };
            Command::Analyze { source }
        }
        "verify" => Command::Verify {
            artifacts: flags
                .get("artifacts")
                .cloned()
                .unwrap_or_else(|| "artifacts".into()),
        },
        "report" => {
            let source = if let Some(n) = flags.get("named") {
                MatrixSource::Named(parse_named(n)?)
            } else if let Some(p) = flags.get("mtx") {
                MatrixSource::MatrixMarket(p.clone())
            } else {
                bail!("report needs --named NAME or --mtx PATH");
            };
            Command::Report { source, out: flags.get("out").cloned() }
        }
        "export" => Command::Export {
            suite: parse_suite(&flags)?,
            dir: flags
                .get("dir")
                .cloned()
                .ok_or_else(|| anyhow!("export needs --dir PATH"))?,
        },
        "serve-bench" => Command::ServeBench {
            suite: parse_suite(&flags)?,
            matrices: parse_usize(&flags, "matrices", 6)?.max(1),
            batches: parse_batches(&flags)?,
            workers: parse_usize(&flags, "workers", 2)?.max(1),
            shards: parse_usize(&flags, "shards", 8)?.max(1),
            queue_cap: parse_usize(&flags, "queue-cap", 1024)?,
            policy: parse_policy(&flags)?,
            pooled: parse_pooled(&flags)?,
            plan_cache_cap: parse_usize(&flags, "plan-cache-cap", 0)?,
            tune: flags.contains_key("tune"),
            trace_out: flags.get("trace-out").cloned(),
            metrics_out: flags.get("metrics-out").cloned(),
            scaling_out: flags.get("scaling-out").cloned(),
        },
        "replay" => Command::Replay {
            suite: parse_suite(&flags)?,
            pattern: parse_pattern(&flags)?,
            requests: parse_usize(&flags, "requests", 2000)?.max(1),
            matrices: parse_usize(&flags, "matrices", 32)?.max(1),
            max_batch: parse_usize(&flags, "max-batch", 16)?.max(1),
            clients: parse_usize(&flags, "clients", 0)?,
            rate: parse_f64(&flags, "rate", 4000.0)?,
            seed: flags
                .get("seed")
                .map(|s| s.parse())
                .transpose()
                .map_err(|_| anyhow!("bad --seed"))?
                .unwrap_or(0x5EED_2019),
            planner: parse_planner(&flags)?,
            json: flags.get("json").cloned(),
            shards: parse_usize(&flags, "shards", 1)?.max(1),
            queue_cap: parse_usize(&flags, "queue-cap", 0)?,
            policy: parse_policy(&flags)?,
            pooled: parse_pooled(&flags)?,
            plan_cache_cap: parse_usize(&flags, "plan-cache-cap", 0)?,
            tune: flags.contains_key("tune"),
            tune_policy: parse_tune_policy(&flags)?,
            tune_state: flags.get("tune-state").cloned(),
            trace_out: flags.get("trace-out").cloned(),
            metrics_out: flags.get("metrics-out").cloned(),
            scaling_out: flags.get("scaling-out").cloned(),
            model: flags.contains_key("model"),
        },
        "check" => Command::Check {
            // The sweep's default scale is `tiny`: every structural
            // class is present and a CI smoke finishes in seconds.
            suite: if flags.contains_key("suite") {
                parse_suite(&flags)?
            } else {
                SuiteSpec::tiny()
            },
            matrices: parse_usize(&flags, "matrices", 8)?.max(1),
            seed: flags
                .get("seed")
                .map(|s| s.parse())
                .transpose()
                .map_err(|_| anyhow!("bad --seed"))?
                .unwrap_or(0xC8EC_2019),
            quick: flags.contains_key("quick"),
            hb: flags.contains_key("hb"),
        },
        "obs-report" => {
            let baseline = flags.get("baseline").cloned();
            let current = flags.get("current").cloned();
            let health_baseline = flags.get("health-baseline").cloned();
            let health_current = flags.get("health-current").cloned();
            if baseline.is_some() != current.is_some() {
                bail!(
                    "obs-report needs --baseline and --current together"
                );
            }
            if health_baseline.is_some() != health_current.is_some() {
                bail!(
                    "obs-report needs --health-baseline and \
                     --health-current together"
                );
            }
            if baseline.is_none() && health_baseline.is_none() {
                bail!(
                    "obs-report needs --baseline/--current and/or \
                     --health-baseline/--health-current"
                );
            }
            Command::ObsReport {
                baseline,
                current,
                efficiency_drop: parse_f64(&flags, "efficiency-drop", 0.10)?,
                knee_shift: parse_usize(&flags, "knee-shift", 2)?,
                share_drift: parse_f64(&flags, "share-drift", 0.10)?,
                queue_p95_ms: flags
                    .get("queue-p95-ms")
                    .map(|s| s.parse())
                    .transpose()
                    .map_err(|_| anyhow!("bad --queue-p95-ms"))?,
                health_baseline,
                health_current,
                recovery_p95_ms: flags
                    .get("recovery-p95-ms")
                    .map(|s| s.parse())
                    .transpose()
                    .map_err(|_| anyhow!("bad --recovery-p95-ms"))?,
                shed_rate_drift: parse_f64(&flags, "shed-rate-drift", 0.05)?,
                dwell_drift: parse_f64(&flags, "dwell-drift", 0.10)?,
            }
        }
        "chaos" => Command::Chaos {
            seed: flags
                .get("seed")
                .map(|s| s.parse())
                .transpose()
                .map_err(|_| anyhow!("bad --seed"))?
                .unwrap_or(0xC4A05),
            scenarios: parse_usize(&flags, "scenarios", 6)?.max(1),
            requests: parse_usize(&flags, "requests", 160)?.max(1),
            matrices: parse_usize(&flags, "matrices", 4)?.max(1),
            shards: parse_usize(&flags, "shards", 3)?.max(1),
            faults: parse_usize(&flags, "faults", 5)?,
            retry_budget: parse_usize(&flags, "retry-budget", 3)?,
            canary: flags.contains_key("canary"),
            health_out: flags.get("health-out").cloned(),
        },
        "info" => Command::Info,
        other => bail!("unknown command '{other}'\n{}", usage()),
    };
    Ok(Cli { command })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_sweep_defaults() {
        let cli = parse(&sv(&["sweep"])).unwrap();
        match cli.command {
            Command::Sweep { threads, schedule, placement, .. } => {
                assert_eq!(threads, vec![1, 2, 3, 4]);
                assert_eq!(schedule, Schedule::CsrRowStatic);
                assert_eq!(placement, Placement::CoreGroupFirst);
            }
            _ => panic!("wrong command"),
        }
    }

    #[test]
    fn parses_flags() {
        let cli = parse(&sv(&[
            "sweep",
            "--suite",
            "tiny",
            "--schedule",
            "csr5",
            "--placement",
            "private",
            "--threads",
            "1,2,4",
        ]))
        .unwrap();
        match cli.command {
            Command::Sweep { suite, schedule, placement, threads, .. } => {
                assert_eq!(suite.per_class, SuiteSpec::tiny().per_class);
                assert!(matches!(schedule, Schedule::Csr5Tiles { .. }));
                assert_eq!(placement, Placement::PrivateL2);
                assert_eq!(threads, vec![1, 2, 4]);
            }
            _ => panic!("wrong command"),
        }
    }

    #[test]
    fn parses_sell_schedule() {
        let cli = parse(&sv(&["sweep", "--schedule", "sell"])).unwrap();
        match cli.command {
            Command::Sweep { schedule, .. } => {
                assert_eq!(schedule, Schedule::SellChunks { c: 8, sigma: 64 })
            }
            _ => panic!("wrong command"),
        }
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse(&sv(&[])).is_err());
        assert!(parse(&sv(&["bogus"])).is_err());
        assert!(parse(&sv(&["sweep", "--threads", "2,4"])).is_err());
        assert!(parse(&sv(&["sweep", "--suite", "huge"])).is_err());
        assert!(parse(&sv(&["analyze"])).is_err());
        assert!(parse(&sv(&["analyze", "--named", "nope"])).is_err());
    }

    #[test]
    fn parses_report_and_export() {
        let cli = parse(&sv(&["report", "--named", "debr"])).unwrap();
        assert!(matches!(cli.command, Command::Report { .. }));
        let cli =
            parse(&sv(&["export", "--suite", "tiny", "--dir", "/tmp/x"]))
                .unwrap();
        assert!(matches!(cli.command, Command::Export { .. }));
        assert!(parse(&sv(&["export"])).is_err());
        assert!(parse(&sv(&["report"])).is_err());
    }

    #[test]
    fn parses_serve_bench_defaults() {
        let cli = parse(&sv(&["serve-bench"])).unwrap();
        match cli.command {
            Command::ServeBench {
                matrices,
                batches,
                workers,
                shards,
                queue_cap,
                policy,
                pooled,
                ..
            } => {
                assert_eq!(matrices, 6);
                assert_eq!(batches, vec![1, 2, 4, 8, 16]);
                assert_eq!(workers, 2);
                assert_eq!(shards, 8);
                assert_eq!(queue_cap, 1024);
                assert_eq!(policy, PlacementPolicy::HotReplicate { hot: 2 });
                assert!(pooled, "pooled execution is the default");
            }
            _ => panic!("wrong command"),
        }
        assert!(parse(&sv(&["serve-bench", "--batches", "0,2"])).is_err());
        assert!(parse(&sv(&["serve-bench", "--batches", "x"])).is_err());
    }

    #[test]
    fn parses_pool_spawn_toggle() {
        for (args, want) in [
            (vec!["serve-bench"], true),
            (vec!["serve-bench", "--pool"], true),
            (vec!["serve-bench", "--spawn"], false),
        ] {
            let cli = parse(&sv(&args)).unwrap();
            match cli.command {
                Command::ServeBench { pooled, .. } => {
                    assert_eq!(pooled, want, "{args:?}")
                }
                _ => panic!("wrong command"),
            }
        }
        let cli = parse(&sv(&["replay", "--spawn", "--requests", "10"]))
            .unwrap();
        match cli.command {
            Command::Replay { pooled, requests, .. } => {
                assert!(!pooled);
                assert_eq!(requests, 10, "value flags still parse after a \
                     boolean flag");
            }
            _ => panic!("wrong command"),
        }
        assert!(
            parse(&sv(&["serve-bench", "--pool", "--spawn"])).is_err(),
            "--pool and --spawn are mutually exclusive"
        );
    }

    #[test]
    fn parses_sharding_flags() {
        let cli = parse(&sv(&[
            "serve-bench",
            "--shards",
            "1",
            "--queue-cap",
            "0",
            "--policy",
            "home",
        ]))
        .unwrap();
        match cli.command {
            Command::ServeBench { shards, queue_cap, policy, .. } => {
                assert_eq!(shards, 1);
                assert_eq!(queue_cap, 0);
                assert_eq!(policy, PlacementPolicy::Home);
            }
            _ => panic!("wrong command"),
        }
        let cli = parse(&sv(&[
            "replay",
            "--shards",
            "8",
            "--queue-cap",
            "256",
            "--policy",
            "replicate",
            "--hot",
            "3",
        ]))
        .unwrap();
        match cli.command {
            Command::Replay { shards, queue_cap, policy, .. } => {
                assert_eq!(shards, 8);
                assert_eq!(queue_cap, 256);
                assert_eq!(policy, PlacementPolicy::HotReplicate { hot: 3 });
            }
            _ => panic!("wrong command"),
        }
        assert!(parse(&sv(&["replay", "--policy", "nope"])).is_err());
        assert!(parse(&sv(&["serve-bench", "--shards", "x"])).is_err());
    }

    #[test]
    fn parses_replay_defaults_and_flags() {
        let cli = parse(&sv(&["replay"])).unwrap();
        match cli.command {
            Command::Replay {
                pattern,
                requests,
                matrices,
                max_batch,
                clients,
                planner,
                json,
                ..
            } => {
                assert_eq!(pattern, TrafficPattern::Zipf);
                assert_eq!(requests, 2000);
                assert_eq!(matrices, 32);
                assert_eq!(max_batch, 16);
                assert_eq!(clients, 0);
                assert_eq!(planner, PlannerKind::Learned);
                assert!(json.is_none());
            }
            _ => panic!("wrong command"),
        }
        let cli = parse(&sv(&[
            "replay",
            "--suite",
            "tiny",
            "--pattern",
            "bursty",
            "--clients",
            "8",
            "--planner",
            "heuristic",
            "--requests",
            "100",
            "--json",
            "/tmp/replay.json",
        ]))
        .unwrap();
        match cli.command {
            Command::Replay { pattern, clients, planner, requests, json, .. } => {
                assert_eq!(pattern, TrafficPattern::Bursty);
                assert_eq!(clients, 8);
                assert_eq!(planner, PlannerKind::Heuristic);
                assert_eq!(requests, 100);
                assert_eq!(json.as_deref(), Some("/tmp/replay.json"));
            }
            _ => panic!("wrong command"),
        }
        assert!(parse(&sv(&["replay", "--pattern", "nope"])).is_err());
        assert!(parse(&sv(&["replay", "--planner", "nope"])).is_err());
        assert!(parse(&sv(&["replay", "--requests", "abc"])).is_err());
    }

    #[test]
    fn parses_tune_flags() {
        let cli = parse(&sv(&["replay"])).unwrap();
        match cli.command {
            Command::Replay {
                tune,
                tune_policy,
                tune_state,
                plan_cache_cap,
                ..
            } => {
                assert!(!tune, "tuning is opt-in");
                assert_eq!(tune_policy, TunePolicyKind::Epsilon);
                assert!(tune_state.is_none());
                assert_eq!(plan_cache_cap, 0);
            }
            _ => panic!("wrong command"),
        }
        let cli = parse(&sv(&[
            "replay",
            "--tune",
            "--tune-policy",
            "ucb",
            "--tune-state",
            "/tmp/tune.json",
            "--plan-cache-cap",
            "64",
            "--requests",
            "50",
        ]))
        .unwrap();
        match cli.command {
            Command::Replay {
                tune,
                tune_policy,
                tune_state,
                plan_cache_cap,
                requests,
                ..
            } => {
                assert!(tune);
                assert_eq!(tune_policy, TunePolicyKind::Ucb);
                assert_eq!(tune_state.as_deref(), Some("/tmp/tune.json"));
                assert_eq!(plan_cache_cap, 64);
                assert_eq!(requests, 50, "value flags parse after --tune");
            }
            _ => panic!("wrong command"),
        }
        let cli = parse(&sv(&[
            "serve-bench",
            "--tune",
            "--plan-cache-cap",
            "8",
        ]))
        .unwrap();
        match cli.command {
            Command::ServeBench { tune, plan_cache_cap, .. } => {
                assert!(tune);
                assert_eq!(plan_cache_cap, 8);
            }
            _ => panic!("wrong command"),
        }
        assert!(parse(&sv(&["replay", "--tune-policy", "nope"])).is_err());
    }

    #[test]
    fn parses_observability_flags() {
        let cli = parse(&sv(&["replay"])).unwrap();
        match cli.command {
            Command::Replay { trace_out, metrics_out, .. } => {
                assert!(trace_out.is_none(), "tracing is opt-in");
                assert!(metrics_out.is_none());
            }
            _ => panic!("wrong command"),
        }
        let cli = parse(&sv(&[
            "replay",
            "--trace-out",
            "/tmp/trace.json",
            "--metrics-out",
            "/tmp/metrics.json",
        ]))
        .unwrap();
        match cli.command {
            Command::Replay { trace_out, metrics_out, .. } => {
                assert_eq!(trace_out.as_deref(), Some("/tmp/trace.json"));
                assert_eq!(
                    metrics_out.as_deref(),
                    Some("/tmp/metrics.json")
                );
            }
            _ => panic!("wrong command"),
        }
        let cli = parse(&sv(&[
            "serve-bench",
            "--trace-out",
            "/tmp/sb.json",
            "--metrics-out",
            "/tmp/sbm.json",
        ]))
        .unwrap();
        match cli.command {
            Command::ServeBench { trace_out, metrics_out, .. } => {
                assert_eq!(trace_out.as_deref(), Some("/tmp/sb.json"));
                assert_eq!(metrics_out.as_deref(), Some("/tmp/sbm.json"));
            }
            _ => panic!("wrong command"),
        }
        assert!(
            parse(&sv(&["replay", "--trace-out"])).is_err(),
            "--trace-out needs a value"
        );
    }

    #[test]
    fn parses_check() {
        let cli = parse(&sv(&["check"])).unwrap();
        match cli.command {
            Command::Check { suite, matrices, quick, hb, .. } => {
                assert_eq!(suite.per_class, SuiteSpec::tiny().per_class);
                assert_eq!(matrices, 8);
                assert!(!quick, "quick mode is opt-in");
                assert!(!hb, "hb analysis is opt-in");
            }
            _ => panic!("wrong command"),
        }
        let cli = parse(&sv(&[
            "check",
            "--suite",
            "fast",
            "--matrices",
            "3",
            "--seed",
            "7",
            "--quick",
            "--hb",
        ]))
        .unwrap();
        match cli.command {
            Command::Check { suite, matrices, seed, quick, hb } => {
                assert_eq!(suite.per_class, SuiteSpec::fast().per_class);
                assert_eq!(matrices, 3);
                assert_eq!(seed, 7);
                assert!(quick);
                assert!(hb);
            }
            _ => panic!("wrong command"),
        }
        assert!(parse(&sv(&["check", "--matrices", "x"])).is_err());
    }

    #[test]
    fn parses_obs_report() {
        let cli = parse(&sv(&[
            "obs-report",
            "--baseline",
            "/tmp/a.json",
            "--current",
            "/tmp/b.json",
        ]))
        .unwrap();
        match cli.command {
            Command::ObsReport {
                baseline,
                current,
                efficiency_drop,
                knee_shift,
                share_drift,
                queue_p95_ms,
                health_baseline,
                health_current,
                ..
            } => {
                assert_eq!(baseline.as_deref(), Some("/tmp/a.json"));
                assert_eq!(current.as_deref(), Some("/tmp/b.json"));
                assert!((efficiency_drop - 0.10).abs() < 1e-12);
                assert_eq!(knee_shift, 2);
                assert!((share_drift - 0.10).abs() < 1e-12);
                assert!(queue_p95_ms.is_none(), "SLO derives from baseline");
                assert!(health_baseline.is_none());
                assert!(health_current.is_none());
            }
            _ => panic!("wrong command"),
        }
        let cli = parse(&sv(&[
            "obs-report",
            "--baseline",
            "a",
            "--current",
            "b",
            "--efficiency-drop",
            "0.2",
            "--knee-shift",
            "4",
            "--share-drift",
            "0.05",
            "--queue-p95-ms",
            "1.5",
        ]))
        .unwrap();
        match cli.command {
            Command::ObsReport {
                efficiency_drop,
                knee_shift,
                share_drift,
                queue_p95_ms,
                ..
            } => {
                assert!((efficiency_drop - 0.2).abs() < 1e-12);
                assert_eq!(knee_shift, 4);
                assert!((share_drift - 0.05).abs() < 1e-12);
                assert_eq!(queue_p95_ms, Some(1.5));
            }
            _ => panic!("wrong command"),
        }
        assert!(parse(&sv(&["obs-report"])).is_err(), "paths are required");
        assert!(
            parse(&sv(&["obs-report", "--baseline", "a"])).is_err(),
            "--current is required"
        );
    }

    #[test]
    fn parses_obs_report_health_pair() {
        // The health pair alone is a valid invocation.
        let cli = parse(&sv(&[
            "obs-report",
            "--health-baseline",
            "/tmp/ha.json",
            "--health-current",
            "/tmp/hb.json",
            "--shed-rate-drift",
            "0.02",
            "--dwell-drift",
            "0.25",
            "--recovery-p95-ms",
            "9.5",
        ]))
        .unwrap();
        match cli.command {
            Command::ObsReport {
                baseline,
                health_baseline,
                health_current,
                recovery_p95_ms,
                shed_rate_drift,
                dwell_drift,
                ..
            } => {
                assert!(baseline.is_none());
                assert_eq!(health_baseline.as_deref(), Some("/tmp/ha.json"));
                assert_eq!(health_current.as_deref(), Some("/tmp/hb.json"));
                assert_eq!(recovery_p95_ms, Some(9.5));
                assert!((shed_rate_drift - 0.02).abs() < 1e-12);
                assert!((dwell_drift - 0.25).abs() < 1e-12);
            }
            _ => panic!("wrong command"),
        }
        // Both pairs together also parse.
        assert!(parse(&sv(&[
            "obs-report",
            "--baseline",
            "a",
            "--current",
            "b",
            "--health-baseline",
            "ha",
            "--health-current",
            "hb",
        ]))
        .is_ok());
        // Half a health pair is an error, like half a scaling pair.
        assert!(parse(&sv(&[
            "obs-report",
            "--health-baseline",
            "ha"
        ]))
        .is_err());
        assert!(parse(&sv(&[
            "obs-report",
            "--baseline",
            "a",
            "--current",
            "b",
            "--health-current",
            "hb",
        ]))
        .is_err());
    }

    #[test]
    fn parses_chaos() {
        let cli = parse(&sv(&["chaos"])).unwrap();
        match cli.command {
            Command::Chaos {
                seed,
                scenarios,
                requests,
                matrices,
                shards,
                faults,
                retry_budget,
                canary,
                health_out,
            } => {
                assert_eq!(seed, 0xC4A05);
                assert_eq!(scenarios, 6);
                assert_eq!(requests, 160);
                assert_eq!(matrices, 4);
                assert_eq!(shards, 3);
                assert_eq!(faults, 5);
                assert_eq!(retry_budget, 3);
                assert!(!canary, "the canary is opt-in");
                assert!(health_out.is_none());
            }
            _ => panic!("wrong command"),
        }
        let cli = parse(&sv(&[
            "chaos",
            "--seed",
            "42",
            "--scenarios",
            "2",
            "--canary",
            "--requests",
            "48",
            "--health-out",
            "/tmp/health.json",
        ]))
        .unwrap();
        match cli.command {
            Command::Chaos { seed, scenarios, requests, canary, health_out, .. } => {
                assert_eq!(seed, 42);
                assert_eq!(scenarios, 2);
                assert_eq!(requests, 48, "value flags parse after --canary");
                assert!(canary);
                assert_eq!(health_out.as_deref(), Some("/tmp/health.json"));
            }
            _ => panic!("wrong command"),
        }
        assert!(parse(&sv(&["chaos", "--scenarios", "x"])).is_err());
    }

    #[test]
    fn parses_scaling_flags() {
        let cli = parse(&sv(&["replay"])).unwrap();
        match cli.command {
            Command::Replay { scaling_out, model, .. } => {
                assert!(scaling_out.is_none());
                assert!(!model, "kernels execute by default");
            }
            _ => panic!("wrong command"),
        }
        let cli = parse(&sv(&[
            "replay",
            "--model",
            "--scaling-out",
            "/tmp/scaling.json",
            "--requests",
            "25",
        ]))
        .unwrap();
        match cli.command {
            Command::Replay { scaling_out, model, requests, .. } => {
                assert!(model);
                assert_eq!(scaling_out.as_deref(), Some("/tmp/scaling.json"));
                assert_eq!(requests, 25, "value flags parse after --model");
            }
            _ => panic!("wrong command"),
        }
        let cli = parse(&sv(&[
            "serve-bench",
            "--scaling-out",
            "/tmp/sb-scaling.json",
        ]))
        .unwrap();
        match cli.command {
            Command::ServeBench { scaling_out, .. } => {
                assert_eq!(
                    scaling_out.as_deref(),
                    Some("/tmp/sb-scaling.json")
                );
            }
            _ => panic!("wrong command"),
        }
    }

    #[test]
    fn parses_named() {
        let cli =
            parse(&sv(&["analyze", "--named", "exdata_1"])).unwrap();
        match cli.command {
            Command::Analyze { source: MatrixSource::Named(m) } => {
                assert_eq!(m, NamedMatrix::Exdata1)
            }
            _ => panic!("wrong command"),
        }
    }
}
