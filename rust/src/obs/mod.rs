//! Observability: stage-level tracing and a unified metrics registry
//! for the serve path.
//!
//! The paper's method is decomposition — attributing where time goes
//! (sync overhead, load imbalance, memory stalls) per kernel phase as
//! thread counts rise. The serving engine reproduces that
//! decomposition live on its own traffic:
//!
//! * [`trace::TraceRecorder`] — a lock-free, alloc-free-on-hot-path
//!   span recorder: per-lane fixed-capacity ring buffers of
//!   stage-tagged spans ([`Stage`]), stamped with virtual time under
//!   replay and wall time under live serving, exportable as Chrome
//!   `trace_event` JSON (open in `chrome://tracing` or Perfetto) and
//!   as an aggregated per-stage/per-schedule flame table;
//! * [`metrics::MetricsRegistry`] — counters, gauges, and
//!   log-bucketed latency histograms behind one snapshot API, the
//!   schema that unifies today's scattered surfaces (`ServeStats`,
//!   shard tables, `PlanCache` hit/evict counters, `ExecPool` worker
//!   occupancy, autotune arm stats) — see
//!   `ServeEngine::metrics_snapshot`;
//! * [`scaling::ScalingProfiler`] — the always-on scalability
//!   attribution layer on top of both: per-batch decomposition of the
//!   gap to linear speedup (load imbalance / dispatch+sync overhead /
//!   memory-bound residual), per-fingerprint efficiency curves with
//!   knee detection, the `ft2000.scaling.v1` snapshot, and the
//!   baseline/compare regression gate behind `ft2000-spmv obs-report`.
//!
//! Tracing is off by default ([`TraceConfig`]); when off, the serve
//! path pays one branch per would-be span. When on, recording is a
//! handful of atomic stores into preallocated rings — the zero-alloc
//! steady-state contract of `tests/alloc.rs` holds with tracing
//! enabled, and the `obs` bench section gates overhead at <= 2%.

pub mod metrics;
pub mod scaling;
pub mod trace;

pub use metrics::{Counter, Gauge, Histogram, MetricsRegistry};
pub use scaling::{
    CompareThresholds, GapComponents, GapTotals, QueueWaitSummary,
    ScalingProfiler,
};
pub use trace::{chrome_document, ClockMode, TraceRecorder};

/// The serve-path stages a span can be tagged with. Every stage a
/// request passes through on its way from admission to an autotune
/// observation has exactly one tag, so a trace decomposes end-to-end
/// latency without gaps or double counting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Request routing + queue admission (`ShardedServer::submit`).
    Admission,
    /// Enqueue-to-dispatch wait (drain loops, replay timelines).
    QueueWait,
    /// Plan-cache lookup (tuner arm selection included).
    PlanLookup,
    /// Plan construction on a cache miss: partitioning + format
    /// conversion (same interval as the missing lookup).
    Partition,
    /// Kernel execution — per worker when pooled, per dispatch
    /// otherwise.
    Kernel,
    /// Post-kernel reduction + telemetry accounting.
    Reduce,
    /// Autotuner feedback (arm update, promotion/demotion check).
    AutotuneObserve,
}

/// Number of [`Stage`] variants.
pub const STAGE_COUNT: usize = 7;

impl Stage {
    /// The stage tag as it appears in trace events and metric names.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Admission => "admission",
            Stage::QueueWait => "queue_wait",
            Stage::PlanLookup => "plan_lookup",
            Stage::Partition => "partition",
            Stage::Kernel => "kernel",
            Stage::Reduce => "reduce",
            Stage::AutotuneObserve => "autotune_observe",
        }
    }

    /// All stages, in serve-path order.
    pub fn all() -> [Stage; STAGE_COUNT] {
        [
            Stage::Admission,
            Stage::QueueWait,
            Stage::PlanLookup,
            Stage::Partition,
            Stage::Kernel,
            Stage::Reduce,
            Stage::AutotuneObserve,
        ]
    }

    /// Stable index (0..[`STAGE_COUNT`]).
    pub fn index(self) -> usize {
        match self {
            Stage::Admission => 0,
            Stage::QueueWait => 1,
            Stage::PlanLookup => 2,
            Stage::Partition => 3,
            Stage::Kernel => 4,
            Stage::Reduce => 5,
            Stage::AutotuneObserve => 6,
        }
    }

    /// Inverse of [`Stage::index`].
    pub fn from_index(i: usize) -> Option<Stage> {
        Stage::all().get(i).copied()
    }
}

/// Tracing knobs, plumbed from the CLI (`--trace-out` enables it).
/// `Copy` on purpose: it rides inside `ReplayConfig`/`ShardConfig`,
/// which are `Copy`.
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    /// Master switch — off by default; an engine without a recorder
    /// attached pays one `Option` branch per would-be span.
    pub enabled: bool,
    /// Record every `sample`-th span (deterministic modulo counter;
    /// 0 and 1 both mean "every span").
    pub sample: u32,
    /// Span slots per lane ring; older spans are overwritten once a
    /// lane wraps.
    pub ring_capacity: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { enabled: false, sample: 1, ring_capacity: 8192 }
    }
}

impl TraceConfig {
    /// An enabled config with default sampling and capacity.
    pub fn on() -> Self {
        TraceConfig { enabled: true, ..TraceConfig::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_names_and_indices_roundtrip() {
        let all = Stage::all();
        assert_eq!(all.len(), STAGE_COUNT);
        for (i, s) in all.iter().enumerate() {
            assert_eq!(s.index(), i);
            assert_eq!(Stage::from_index(i), Some(*s));
            assert!(!s.name().is_empty());
        }
        assert_eq!(Stage::from_index(STAGE_COUNT), None);
        // The seven tags the acceptance criteria name.
        let names: Vec<&str> = all.iter().map(|s| s.name()).collect();
        assert_eq!(
            names,
            [
                "admission",
                "queue_wait",
                "plan_lookup",
                "partition",
                "kernel",
                "reduce",
                "autotune_observe"
            ]
        );
    }

    #[test]
    fn config_defaults_off() {
        let c = TraceConfig::default();
        assert!(!c.enabled);
        assert!(TraceConfig::on().enabled);
    }
}
