//! Lock-free stage-span recorder with Chrome `trace_event` export.
//!
//! Recording discipline: each lane (dispatcher = lane 0, pool worker
//! `i` = lane `i + 1`) owns a fixed ring of span slots whose fields
//! are all atomics. A writer claims a slot with one `fetch_add` on
//! the lane cursor and stores four words — no locks, no heap, so the
//! zero-allocation steady-state contract of the serve path survives
//! with tracing on. Concurrent writers that lap the ring may tear a
//! slot (fields from two spans); that is a bounded reporting
//! inaccuracy, never unsoundness, and export happens quiescently
//! (after the run) in practice.
//!
//! Clocks: under live serving spans are stamped with wall time from
//! a shared epoch; under deterministic replay the harness advances a
//! virtual clock ([`TraceRecorder::set_virtual_s`]) and spans are
//! stamped with it. Engine-internal stages (plan lookup, reduce, ...)
//! always measure their *duration* in wall time — the real cost of
//! the code — while the timestamp follows the recorder's clock, so a
//! replayed trace lines up on the virtual timeline.

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::time::Instant;

use super::{Stage, TraceConfig, STAGE_COUNT};
use crate::util::json::Json;
use crate::util::ordatomic::{OrdAtomicU64, OrdAtomicUsize};
use crate::util::table::Table;

/// What clock spans are stamped with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClockMode {
    /// Wall time since the recorder's construction (live serving).
    Wall,
    /// A virtual clock the replay harness advances explicitly.
    Virtual,
}

/// Schedule attribution code carried by a span: 0 = none, else
/// `autotune::ladder::schedule_code + 1`.
pub(crate) const SCHED_NONE: usize = 0;

/// Name of a span's schedule code (see [`SCHED_NONE`]). Mirrors
/// `autotune::ladder::schedule_code` ordering.
fn sched_code_name(code: usize) -> &'static str {
    match code {
        1 => "csr-static",
        2 => "csr-balanced",
        3 => "csr5-tiles",
        4 => "csr-dynamic",
        5 => "sell",
        _ => "-",
    }
}

/// One recorded span. All fields atomic so ring wrap-around under
/// concurrent writers is a benign tear, not a data race. The fields
/// are declared `racy_ok` to `check::hb`: a lapped ring may mix two
/// spans' words, which `validate` bounds (a reporting inaccuracy,
/// never unsoundness) — exactly the documented-benign class the
/// detector must not report.
struct SpanSlot {
    /// `Stage::index() + 1`; 0 = slot never written.
    stage: OrdAtomicUsize,
    /// Schedule code (see [`sched_code_name`]).
    sched: OrdAtomicUsize,
    /// Span start, µs on the recorder's clock (f64 bits).
    start_us: OrdAtomicU64,
    /// Span duration, µs (f64 bits).
    dur_us: OrdAtomicU64,
}

const SLOT_TEAR: &str = "ring lap may tear a span; bounded by validate()";

impl SpanSlot {
    fn empty() -> SpanSlot {
        SpanSlot {
            stage: OrdAtomicUsize::racy_ok(0, "trace.slot.stage", SLOT_TEAR),
            sched: OrdAtomicUsize::racy_ok(0, "trace.slot.sched", SLOT_TEAR),
            start_us: OrdAtomicU64::racy_ok(
                0,
                "trace.slot.start_us",
                SLOT_TEAR,
            ),
            dur_us: OrdAtomicU64::racy_ok(0, "trace.slot.dur_us", SLOT_TEAR),
        }
    }
}

/// One lane's span ring.
struct Lane {
    next: OrdAtomicUsize,
    slots: Box<[SpanSlot]>,
}

impl Lane {
    fn new(capacity: usize) -> Lane {
        Lane {
            next: OrdAtomicUsize::named(0, "trace.lane.next"),
            slots: (0..capacity).map(|_| SpanSlot::empty()).collect(),
        }
    }
}

/// The recorder: per-lane rings + the clock + the sampling counter.
/// Shared as an `Arc` between the engine, its pool, the queues, and
/// the replay harness.
pub struct TraceRecorder {
    cfg: TraceConfig,
    mode: ClockMode,
    epoch: Instant,
    /// Virtual now, µs (f64 bits) — only meaningful under
    /// [`ClockMode::Virtual`].
    virtual_us: OrdAtomicU64,
    /// Deterministic sampling counter (every `cfg.sample`-th span).
    counter: OrdAtomicUsize,
    /// Schedule code of the dispatch currently executing — set by the
    /// engine before handing work to the pool so per-worker kernel
    /// spans carry attribution. Under concurrent dispatchers this is
    /// last-writer-wins: a bounded attribution approximation.
    kernel_ctx: OrdAtomicUsize,
    lanes: Box<[Lane]>,
}

impl TraceRecorder {
    /// `n_lanes` = 1 (dispatcher only) + the pool worker count when
    /// per-worker kernel spans are wanted.
    pub fn new(cfg: TraceConfig, mode: ClockMode, n_lanes: usize) -> Self {
        let cap = cfg.ring_capacity.max(1);
        TraceRecorder {
            cfg,
            mode,
            epoch: Instant::now(),
            virtual_us: OrdAtomicU64::named(
                0f64.to_bits(),
                "trace.virtual_us",
            ),
            counter: OrdAtomicUsize::named(0, "trace.sample_counter"),
            kernel_ctx: OrdAtomicUsize::racy_ok(
                SCHED_NONE,
                "trace.kernel_ctx",
                "last-writer-wins attribution under concurrent dispatch",
            ),
            lanes: (0..n_lanes.max(1)).map(|_| Lane::new(cap)).collect(),
        }
    }

    pub fn config(&self) -> TraceConfig {
        self.cfg
    }

    pub fn mode(&self) -> ClockMode {
        self.mode
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Current time on the recorder's clock, in µs.
    pub fn now_us(&self) -> f64 {
        match self.mode {
            ClockMode::Wall => self.epoch.elapsed().as_secs_f64() * 1e6,
            ClockMode::Virtual => {
                // ord: Relaxed load — the replay driver advances the
                // clock before dispatch; the pool's fork edge (not
                // this cell) publishes it to the workers.
                f64::from_bits(self.virtual_us.load(Ordering::Relaxed))
            }
        }
    }

    /// Advance the virtual clock (replay harness only).
    pub fn set_virtual_s(&self, t_s: f64) {
        // lint:allow(relaxed-store) ord: single-writer replay driver;
        // the dispatch fork edge orders it before any worker read
        // (hb-verified).
        self.virtual_us.store((t_s * 1e6).to_bits(), Ordering::Relaxed);
    }

    /// Deterministic sampling decision: true for every
    /// `cfg.sample`-th call (always true at sample <= 1).
    #[inline]
    pub fn sampled(&self) -> bool {
        let s = self.cfg.sample;
        if s <= 1 {
            return true;
        }
        // ord: Relaxed RMW — atomic arbitration is all the sampling
        // counter needs; no data is published through it.
        self.counter.fetch_add(1, Ordering::Relaxed) % s as usize == 0
    }

    /// Set the schedule attribution for subsequent kernel spans.
    #[inline]
    pub fn set_kernel_ctx(&self, sched_code: usize) {
        // lint:allow(relaxed-store) ord: racy_ok cell — last-writer-
        // wins attribution is the documented contract.
        self.kernel_ctx.store(sched_code, Ordering::Relaxed);
    }

    /// The current kernel attribution code.
    #[inline]
    pub fn kernel_ctx(&self) -> usize {
        // ord: Relaxed load of the racy_ok attribution cell.
        self.kernel_ctx.load(Ordering::Relaxed)
    }

    /// Record one span. Lock-free, alloc-free: one `fetch_add` + four
    /// atomic stores. `lane` is clamped into the lane set; sampling
    /// must already have been decided (call [`TraceRecorder::sampled`]
    /// once per span so multi-span paths stay consistent).
    #[inline]
    pub fn record(
        &self,
        lane: usize,
        stage: Stage,
        sched_code: usize,
        start_us: f64,
        dur_us: f64,
    ) {
        let lane = &self.lanes[lane.min(self.lanes.len() - 1)];
        // ord: Relaxed RMW — the cursor only arbitrates slot claims;
        // readers treat slot contents as possibly torn (racy_ok).
        let idx = lane.next.fetch_add(1, Ordering::Relaxed);
        let slot = &lane.slots[idx % lane.slots.len()];
        // lint:allow(relaxed-store) ord: racy_ok slot fields — a ring
        // lap may tear a span; validate() bounds the damage.
        slot.stage.store(stage.index() + 1, Ordering::Relaxed);
        // lint:allow(relaxed-store) ord: racy_ok slot field (above).
        slot.sched.store(sched_code, Ordering::Relaxed);
        // lint:allow(relaxed-store) ord: racy_ok slot field (above).
        slot.start_us.store(start_us.to_bits(), Ordering::Relaxed);
        // lint:allow(relaxed-store) ord: racy_ok slot field (above).
        slot.dur_us.store(dur_us.to_bits(), Ordering::Relaxed);
    }

    /// Convenience: sample-gated span ending now, starting `dur_us`
    /// earlier on the recorder's clock.
    #[inline]
    pub fn record_elapsed(
        &self,
        lane: usize,
        stage: Stage,
        sched_code: usize,
        dur_us: f64,
    ) {
        if self.sampled() {
            let now = self.now_us();
            self.record(lane, stage, sched_code, now - dur_us, dur_us);
        }
    }

    /// Spans currently held (post-wrap: the ring capacities).
    pub fn span_count(&self) -> usize {
        self.lanes
            .iter()
            // ord: Relaxed load — monotone cursor snapshot.
            .map(|l| l.next.load(Ordering::Relaxed).min(l.slots.len()))
            .sum()
    }

    /// Spans ever recorded, including ones overwritten by ring wrap.
    pub fn spans_recorded(&self) -> usize {
        // ord: Relaxed load — monotone cursor snapshot.
        self.lanes.iter().map(|l| l.next.load(Ordering::Relaxed)).sum()
    }

    /// Spans lost to ring wrap: recorded but no longer held. Exported
    /// as `trace.spans.overwritten` in `ft2000.metrics.v1` so ring
    /// loss is visible instead of silent.
    pub fn spans_overwritten(&self) -> usize {
        self.spans_recorded().saturating_sub(self.span_count())
    }

    /// Well-formedness validation of the recorded rings — reused by
    /// the deterministic interleaving harness (`check::interleave`)
    /// and the `ft2000-spmv check` CLI smoke. Returns one message per
    /// violation; empty means clean.
    ///
    /// Assumes the recorder's own usage discipline: quiescence at
    /// validation time and one writer per lane (dispatcher = lane 0,
    /// worker `i` = lane `i + 1`). Under it, every slot inside a
    /// lane's held window must decode to a known stage (a zero tag
    /// there is a torn or lost record), carry a known schedule code
    /// and finite non-negative timestamps, and per-lane span *end*
    /// times must be non-decreasing in ring order (oldest to newest
    /// through a wrap) — spans are recorded at their end, so a
    /// backwards end-time means reordered or torn records. Slots
    /// beyond the cursor of an unwrapped lane must be untouched.
    /// Spans are Chrome `ph:"X"` complete events (begin/end balanced
    /// by construction), so no begin/end pairing check is needed.
    pub fn validate(&self) -> Vec<String> {
        const MAX_FINDINGS: usize = 64;
        let mut findings = Vec::new();
        for (li, lane) in self.lanes.iter().enumerate() {
            // ord: Relaxed loads throughout — validate runs at
            // quiescence (the caller's join/latch orders the writes).
            let next = lane.next.load(Ordering::Relaxed);
            let len = lane.slots.len();
            let held = next.min(len);
            let mut prev_end = f64::NEG_INFINITY;
            for k in 0..held {
                // Oldest-to-newest: a wrapped ring starts at the
                // cursor, an unwrapped one at slot 0.
                let pos = if next <= len { k } else { (next + k) % len };
                let slot = &lane.slots[pos];
                // ord: Relaxed load — quiescent (see loop head).
                let tag = slot.stage.load(Ordering::Relaxed);
                match tag.checked_sub(1).and_then(Stage::from_index) {
                    None if tag == 0 => {
                        findings.push(format!(
                            "lane {li} slot {pos}: torn or lost record \
                             inside the held window"
                        ));
                        continue;
                    }
                    None => {
                        findings.push(format!(
                            "lane {li} slot {pos}: unknown stage tag {tag}"
                        ));
                        continue;
                    }
                    Some(_) => {}
                }
                // ord: Relaxed load — quiescent (see loop head).
                let sched = slot.sched.load(Ordering::Relaxed);
                if sched > 5 {
                    findings.push(format!(
                        "lane {li} slot {pos}: invalid schedule code {sched}"
                    ));
                }
                // ord: Relaxed loads — quiescent (see loop head).
                let start =
                    f64::from_bits(slot.start_us.load(Ordering::Relaxed));
                let dur = f64::from_bits(slot.dur_us.load(Ordering::Relaxed));
                if !start.is_finite()
                    || start < 0.0
                    || !dur.is_finite()
                    || dur < 0.0
                {
                    findings.push(format!(
                        "lane {li} slot {pos}: bad timestamp/duration \
                         ({start} us + {dur} us)"
                    ));
                    continue;
                }
                let end = start + dur;
                // 1 ns slack: `record_elapsed` derives start as
                // `now - dur`, so re-adding can round by an ulp.
                if end + 1e-3 < prev_end {
                    findings.push(format!(
                        "lane {li} slot {pos}: end time went backwards \
                         ({end} us after {prev_end} us)"
                    ));
                }
                prev_end = prev_end.max(end);
            }
            if next < len {
                for (pos, slot) in lane.slots.iter().enumerate().skip(held) {
                    // ord: Relaxed load — quiescent (see loop head).
                    if slot.stage.load(Ordering::Relaxed) != 0 {
                        findings.push(format!(
                            "lane {li} slot {pos}: record beyond the lane \
                             cursor {next}"
                        ));
                    }
                }
            }
            if findings.len() > MAX_FINDINGS {
                break;
            }
        }
        if findings.len() > MAX_FINDINGS {
            let extra = findings.len() - MAX_FINDINGS;
            findings.truncate(MAX_FINDINGS);
            findings.push(format!("... {extra} more finding(s) suppressed"));
        }
        findings
    }

    fn each_span(&self, mut f: impl FnMut(usize, Stage, usize, f64, f64)) {
        for (lane_idx, lane) in self.lanes.iter().enumerate() {
            // ord: Relaxed loads throughout — export runs at
            // quiescence; a torn slot decodes bounded-wrong, never UB.
            let held =
                lane.next.load(Ordering::Relaxed).min(lane.slots.len());
            for slot in &lane.slots[..held] {
                // ord: Relaxed load — quiescent (see loop head).
                let tag = slot.stage.load(Ordering::Relaxed);
                let Some(stage) = tag.checked_sub(1).and_then(Stage::from_index)
                else {
                    continue;
                };
                // ord: Relaxed loads — quiescent (see loop head).
                f(
                    lane_idx,
                    stage,
                    slot.sched.load(Ordering::Relaxed),
                    f64::from_bits(slot.start_us.load(Ordering::Relaxed)),
                    f64::from_bits(slot.dur_us.load(Ordering::Relaxed)),
                );
            }
        }
    }

    /// Held spans as Chrome `trace_event` objects (`ph: "X"` complete
    /// events), `pid` distinguishing shards in a merged export.
    pub fn chrome_events(&self, pid: usize) -> Vec<Json> {
        let mut events = Vec::with_capacity(self.span_count());
        self.each_span(|lane, stage, sched, start_us, dur_us| {
            let mut args = BTreeMap::new();
            if sched != SCHED_NONE {
                args.insert(
                    "schedule".to_string(),
                    Json::Str(sched_code_name(sched).to_string()),
                );
            }
            let obj: BTreeMap<String, Json> = [
                ("name".to_string(), Json::Str(stage.name().to_string())),
                ("cat".to_string(), Json::Str("serve".to_string())),
                ("ph".to_string(), Json::Str("X".to_string())),
                ("ts".to_string(), Json::Num(start_us)),
                ("dur".to_string(), Json::Num(dur_us)),
                ("pid".to_string(), Json::Num(pid as f64)),
                ("tid".to_string(), Json::Num(lane as f64)),
                ("args".to_string(), Json::Obj(args)),
            ]
            .into_iter()
            .collect();
            events.push(Json::Obj(obj));
        });
        // Stable export order (lanes interleave arbitrarily).
        events.sort_by(|a, b| {
            let ts = |e: &Json| {
                e.get("ts").and_then(Json::as_f64).unwrap_or(0.0)
            };
            ts(a).partial_cmp(&ts(b)).unwrap_or(std::cmp::Ordering::Equal)
        });
        events
    }

    /// Full single-recorder Chrome trace document.
    pub fn export_chrome(&self) -> Json {
        chrome_document(self.chrome_events(0))
    }

    /// Aggregate held spans into (stage, schedule) -> (count,
    /// total_us) cells, raw: sampled spans count once, whatever the
    /// sampling rate. [`TraceRecorder::flame_cells_scaled`] corrects
    /// for sampling.
    pub fn flame_cells(&self) -> BTreeMap<(usize, usize), (u64, f64)> {
        let mut cells: BTreeMap<(usize, usize), (u64, f64)> =
            BTreeMap::new();
        self.each_span(|_, stage, sched, _, dur_us| {
            let cell = cells.entry((stage.index(), sched)).or_insert((0, 0.0));
            cell.0 += 1;
            cell.1 += dur_us;
        });
        cells
    }

    /// [`TraceRecorder::flame_cells`] scaled by the 1-in-N sampling
    /// rate: with `sample = N`, each held span stands for ~N executed
    /// ones, so counts and totals are multiplied by N to estimate the
    /// unsampled truth. (Raw sums under sampling understate absolute
    /// stage time by the sampling factor — the bias the flame table
    /// used to carry.)
    pub fn flame_cells_scaled(&self) -> BTreeMap<(usize, usize), (u64, f64)> {
        let rate = self.cfg.sample.max(1) as u64;
        let mut cells = self.flame_cells();
        for (count, us) in cells.values_mut() {
            *count *= rate;
            *us *= rate as f64;
        }
        cells
    }

    /// The per-stage/per-schedule flame table (serve-path order),
    /// sampling-corrected: spans and totals are the scaled estimates
    /// of [`TraceRecorder::flame_cells_scaled`] (identical to the raw
    /// sums at full sampling).
    pub fn flame_table(&self) -> Table {
        let cells = self.flame_cells_scaled();
        let total: f64 = cells.values().map(|(_, us)| us).sum();
        let rate = self.cfg.sample.max(1);
        let title = if rate > 1 {
            format!(
                "Stage flame (per-stage/per-schedule span aggregate, \
                 x{rate} sampling estimate)"
            )
        } else {
            "Stage flame (per-stage/per-schedule span aggregate)"
                .to_string()
        };
        let mut t = Table::new(
            title,
            &["stage", "schedule", "spans", "total ms", "mean us", "share"],
        );
        for stage in Stage::all() {
            for ((si, sched), (count, us)) in &cells {
                if *si != stage.index() {
                    continue;
                }
                t.row(vec![
                    stage.name().to_string(),
                    sched_code_name(*sched).to_string(),
                    count.to_string(),
                    format!("{:.3}", us / 1e3),
                    format!("{:.2}", us / *count as f64),
                    if total > 0.0 {
                        format!("{:.1}%", 100.0 * us / total)
                    } else {
                        "n/a".to_string()
                    },
                ]);
            }
        }
        t
    }
}

impl std::fmt::Debug for TraceRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceRecorder")
            .field("mode", &self.mode)
            .field("lanes", &self.lanes.len())
            .field("spans_recorded", &self.spans_recorded())
            .finish_non_exhaustive()
    }
}

/// Wrap trace events into the Chrome trace-document object form
/// (what `chrome://tracing` and Perfetto open directly).
pub fn chrome_document(events: Vec<Json>) -> Json {
    Json::Obj(
        [
            (
                "displayTimeUnit".to_string(),
                Json::Str("ms".to_string()),
            ),
            ("traceEvents".to_string(), Json::Arr(events)),
        ]
        .into_iter()
        .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(cap: usize, sample: u32) -> TraceConfig {
        TraceConfig { enabled: true, sample, ring_capacity: cap }
    }

    #[test]
    fn records_and_exports_chrome_events() {
        let rec = TraceRecorder::new(cfg(16, 1), ClockMode::Virtual, 2);
        rec.set_virtual_s(1.0);
        assert_eq!(rec.now_us(), 1e6);
        rec.record(0, Stage::QueueWait, SCHED_NONE, 0.0, 250.0);
        rec.record(1, Stage::Kernel, 1, 1e6, 42.0);
        assert_eq!(rec.span_count(), 2);
        let doc = rec.export_chrome();
        let parsed =
            crate::util::json::parse(&doc.to_string()).expect("valid JSON");
        let events =
            parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(
            events[0].get("name").unwrap().as_str(),
            Some("queue_wait")
        );
        let kernel = &events[1];
        assert_eq!(kernel.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(kernel.get("tid").unwrap().as_usize(), Some(1));
        assert_eq!(kernel.get("dur").unwrap().as_f64(), Some(42.0));
        assert_eq!(
            kernel.get("args").unwrap().get("schedule").unwrap().as_str(),
            Some("csr-static")
        );
    }

    #[test]
    fn ring_wraps_without_growing() {
        let rec = TraceRecorder::new(cfg(4, 1), ClockMode::Wall, 1);
        for i in 0..100 {
            rec.record(0, Stage::Kernel, SCHED_NONE, i as f64, 1.0);
        }
        assert_eq!(rec.span_count(), 4);
        assert_eq!(rec.spans_recorded(), 100);
        // The ring holds the most recent writes at wrapped indices.
        let cells = rec.flame_cells();
        assert_eq!(cells[&(Stage::Kernel.index(), 0)].0, 4);
    }

    #[test]
    fn sampling_is_deterministic() {
        let rec = TraceRecorder::new(cfg(64, 4), ClockMode::Wall, 1);
        let picks: Vec<bool> = (0..12).map(|_| rec.sampled()).collect();
        assert_eq!(
            picks,
            (0..12).map(|i| i % 4 == 0).collect::<Vec<_>>()
        );
        let all = TraceRecorder::new(cfg(64, 1), ClockMode::Wall, 1);
        assert!((0..12).all(|_| all.sampled()));
    }

    #[test]
    fn flame_table_aggregates_by_stage_and_schedule() {
        let rec = TraceRecorder::new(cfg(64, 1), ClockMode::Virtual, 1);
        rec.record(0, Stage::Kernel, 1, 0.0, 10.0);
        rec.record(0, Stage::Kernel, 1, 10.0, 30.0);
        rec.record(0, Stage::Kernel, 5, 40.0, 5.0);
        rec.record(0, Stage::Reduce, SCHED_NONE, 45.0, 5.0);
        let cells = rec.flame_cells();
        assert_eq!(cells[&(Stage::Kernel.index(), 1)], (2, 40.0));
        assert_eq!(cells[&(Stage::Kernel.index(), 5)], (1, 5.0));
        let md = rec.flame_table().to_markdown();
        assert!(md.contains("kernel"));
        assert!(md.contains("csr-static"));
        assert!(md.contains("sell"));
        assert!(md.contains("reduce"));
    }

    #[test]
    fn flame_scaling_corrects_sampling_bias() {
        // 1-in-4 sampling: only every 4th record lands in the ring,
        // so raw sums understate stage time 4x. The scaled cells (and
        // the flame table built from them) multiply back up.
        let rec = TraceRecorder::new(cfg(64, 4), ClockMode::Virtual, 1);
        for i in 0..8 {
            if rec.sampled() {
                rec.record(0, Stage::Kernel, 1, i as f64 * 10.0, 10.0);
            }
        }
        let raw = rec.flame_cells();
        assert_eq!(raw[&(Stage::Kernel.index(), 1)], (2, 20.0));
        let scaled = rec.flame_cells_scaled();
        assert_eq!(scaled[&(Stage::Kernel.index(), 1)], (8, 80.0));
        let md = rec.flame_table().to_markdown();
        assert!(md.contains("x4 sampling estimate"), "{md}");
        assert!(md.contains("| 8 "), "{md}");
        // Full sampling: scaled == raw, no estimate marker.
        let full = TraceRecorder::new(cfg(64, 1), ClockMode::Virtual, 1);
        full.record(0, Stage::Kernel, 1, 0.0, 10.0);
        assert_eq!(full.flame_cells(), full.flame_cells_scaled());
        assert!(!full.flame_table().to_markdown().contains("estimate"));
    }

    #[test]
    fn overwritten_spans_are_counted() {
        let rec = TraceRecorder::new(cfg(4, 1), ClockMode::Wall, 1);
        for i in 0..100 {
            rec.record(0, Stage::Kernel, SCHED_NONE, i as f64, 1.0);
        }
        assert_eq!(rec.spans_overwritten(), 96);
        let fresh = TraceRecorder::new(cfg(4, 1), ClockMode::Wall, 1);
        assert_eq!(fresh.spans_overwritten(), 0);
    }

    #[test]
    fn validate_accepts_clean_rings_including_wraps() {
        let rec = TraceRecorder::new(cfg(4, 1), ClockMode::Virtual, 2);
        for i in 0..10 {
            rec.set_virtual_s(i as f64);
            rec.record(0, Stage::Kernel, 1, i as f64 * 1e6, 5.0);
        }
        rec.record(1, Stage::Reduce, SCHED_NONE, 3.0, 1.0);
        let f = rec.validate();
        assert!(f.is_empty(), "{f:?}");
        // An untouched recorder is also clean.
        let idle = TraceRecorder::new(cfg(4, 1), ClockMode::Wall, 3);
        assert!(idle.validate().is_empty());
    }

    #[test]
    fn validate_flags_malformed_records() {
        // Bad schedule code and a NaN duration on one record.
        let rec = TraceRecorder::new(cfg(8, 1), ClockMode::Virtual, 1);
        rec.record(0, Stage::Kernel, 9, 10.0, f64::NAN);
        let f = rec.validate();
        assert!(f.iter().any(|m| m.contains("schedule code")), "{f:?}");
        assert!(f.iter().any(|m| m.contains("duration")), "{f:?}");
        // Per-lane end times must not go backwards.
        let rec = TraceRecorder::new(cfg(8, 1), ClockMode::Virtual, 1);
        rec.record(0, Stage::Kernel, 1, 100.0, 1.0);
        rec.record(0, Stage::Kernel, 1, 0.0, 1.0);
        let f = rec.validate();
        assert!(f.iter().any(|m| m.contains("backwards")), "{f:?}");
        // A zeroed tag inside the held window reads as a torn record.
        let rec = TraceRecorder::new(cfg(8, 1), ClockMode::Virtual, 1);
        rec.record(0, Stage::Kernel, 1, 0.0, 1.0);
        rec.record(0, Stage::Reduce, 1, 1.0, 1.0);
        rec.lanes[0].slots[0].stage.store(0, Ordering::Relaxed);
        let f = rec.validate();
        assert!(f.iter().any(|m| m.contains("torn")), "{f:?}");
        // A write past the cursor of an unwrapped lane is flagged.
        let rec = TraceRecorder::new(cfg(8, 1), ClockMode::Virtual, 1);
        rec.lanes[0].slots[5].stage.store(2, Ordering::Relaxed);
        let f = rec.validate();
        assert!(f.iter().any(|m| m.contains("beyond")), "{f:?}");
    }

    #[test]
    fn kernel_ctx_is_shared_attribution() {
        let rec = TraceRecorder::new(cfg(8, 1), ClockMode::Wall, 1);
        assert_eq!(rec.kernel_ctx(), SCHED_NONE);
        rec.set_kernel_ctx(3);
        assert_eq!(rec.kernel_ctx(), 3);
    }
}
