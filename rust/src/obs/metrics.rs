//! Unified metrics registry: counters, gauges, and log-bucketed
//! latency histograms behind one snapshot API with JSON export.
//!
//! Instruments are `Arc`-shared: the hot path holds pre-registered
//! handles and updates them with single atomic operations (no lock,
//! no allocation, no name lookup); the registry's `Mutex`-guarded
//! name map is touched only at registration and snapshot time.

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use crate::util::json::Json;
use crate::util::ordatomic::OrdAtomicU64;

/// Monotone event counter.
pub struct Counter(OrdAtomicU64);

impl Default for Counter {
    fn default() -> Self {
        Counter(OrdAtomicU64::named(0, "metrics.counter"))
    }
}

impl Counter {
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        // ord: Relaxed RMW — monotone counter; snapshots need no
        // ordering, only atomicity.
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        // ord: Relaxed load — counter snapshot.
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-writer-wins instantaneous value (f64 bits in an atomic).
pub struct Gauge(OrdAtomicU64);

impl Default for Gauge {
    fn default() -> Self {
        Gauge(OrdAtomicU64::racy_ok(
            0f64.to_bits(),
            "metrics.gauge",
            "last-writer-wins instantaneous value by contract",
        ))
    }
}

impl Gauge {
    #[inline]
    pub fn set(&self, v: f64) {
        // lint:allow(relaxed-store) ord: racy_ok cell — concurrent
        // setters race benignly; readers take whichever landed last.
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        // ord: Relaxed load of the racy_ok gauge cell.
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Histogram bucket count: powers of two from [`Histogram::BASE_MS`]
/// (1 µs) up — 40 buckets reach ~9 minutes, wide enough for any
/// serve-path latency.
const N_BUCKETS: usize = 40;

/// Log₂-bucketed latency histogram (milliseconds). Observation is
/// two atomic adds plus a bit scan; percentiles interpolate within
/// the bucket's geometric span.
pub struct Histogram {
    /// Bucket `i` counts observations in
    /// `[BASE_MS * 2^i, BASE_MS * 2^(i+1))`; bucket 0 also absorbs
    /// anything smaller, the last bucket anything larger.
    buckets: [OrdAtomicU64; N_BUCKETS],
    count: OrdAtomicU64,
    /// Sum of observed values, ms (f64 bits accumulated as integer
    /// µs to stay associative under concurrency).
    sum_us: OrdAtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| {
                OrdAtomicU64::named(0, "metrics.hist.bucket")
            }),
            count: OrdAtomicU64::named(0, "metrics.hist.count"),
            sum_us: OrdAtomicU64::named(0, "metrics.hist.sum_us"),
        }
    }
}

impl Histogram {
    /// Lower edge of bucket 0: 1 µs, in ms.
    pub const BASE_MS: f64 = 1e-3;

    fn bucket_of(v_ms: f64) -> usize {
        if v_ms <= Self::BASE_MS {
            return 0;
        }
        let b = (v_ms / Self::BASE_MS).log2().floor() as usize;
        b.min(N_BUCKETS - 1)
    }

    /// Lower edge of bucket `i`, ms.
    fn bucket_lo(i: usize) -> f64 {
        Self::BASE_MS * (1u64 << i.min(52)) as f64
    }

    #[inline]
    pub fn observe(&self, v_ms: f64) {
        if !v_ms.is_finite() || v_ms < 0.0 {
            return;
        }
        // ord: Relaxed RMWs — independent monotone accumulators; a
        // snapshot may catch bucket/count mid-update, which percentile
        // math tolerates (bounded staleness, no ordering needed).
        self.buckets[Self::bucket_of(v_ms)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add((v_ms * 1e3) as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        // ord: Relaxed load — accumulator snapshot.
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_ms(&self) -> f64 {
        // ord: Relaxed load — accumulator snapshot.
        self.sum_us.load(Ordering::Relaxed) as f64 / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_ms() / n as f64
        }
    }

    /// p-th percentile (0..=100) by geometric interpolation inside
    /// the covering bucket; 0 with no samples. Bucketed, so accurate
    /// to the bucket's factor-of-two span — the registry's cheap
    /// estimate next to telemetry's P² digests.
    pub fn percentile(&self, p: f64) -> f64 {
        let n = self.count();
        // Empty population and junk `p` both answer 0 (a NaN `p`
        // would otherwise flow through clamp and silently act as p0).
        if n == 0 || !p.is_finite() {
            return 0.0;
        }
        let target = (p.clamp(0.0, 100.0) / 100.0 * n as f64).max(1.0);
        let mut seen = 0u64;
        for i in 0..N_BUCKETS {
            // ord: Relaxed load — bucket snapshot (see observe).
            let c = self.buckets[i].load(Ordering::Relaxed);
            if c == 0 {
                continue;
            }
            if (seen + c) as f64 >= target {
                let frac = (target - seen as f64) / c as f64;
                let lo = Self::bucket_lo(i);
                return lo * 2f64.powf(frac.clamp(0.0, 1.0));
            }
            seen += c;
        }
        Self::bucket_lo(N_BUCKETS - 1)
    }

    /// Non-empty buckets as `[lower_edge_ms, count]` pairs.
    fn buckets_json(&self) -> Json {
        Json::Arr(
            (0..N_BUCKETS)
                .filter_map(|i| {
                    // ord: Relaxed load — bucket snapshot.
                    let c = self.buckets[i].load(Ordering::Relaxed);
                    (c > 0).then(|| {
                        Json::Arr(vec![
                            Json::Num(Self::bucket_lo(i)),
                            Json::Num(c as f64),
                        ])
                    })
                })
                .collect(),
        )
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            [
                ("count".to_string(), Json::Num(self.count() as f64)),
                ("mean_ms".to_string(), Json::Num(self.mean_ms())),
                ("p50_ms".to_string(), Json::Num(self.percentile(50.0))),
                ("p95_ms".to_string(), Json::Num(self.percentile(95.0))),
                ("p99_ms".to_string(), Json::Num(self.percentile(99.0))),
                ("buckets".to_string(), self.buckets_json()),
            ]
            .into_iter()
            .collect(),
        )
    }
}

/// One registered instrument.
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// Name → instrument registry. Registration is get-or-create (two
/// callers registering the same name share the instrument); a name
/// registered as one kind stays that kind.
#[derive(Default)]
pub struct MetricsRegistry {
    inner: Mutex<BTreeMap<String, Metric>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut inner = self.inner.lock().unwrap();
        match inner
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::default())))
        {
            Metric::Counter(c) => c.clone(),
            _ => panic!("metric '{name}' already registered as another kind"),
        }
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut inner = self.inner.lock().unwrap();
        match inner
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::default())))
        {
            Metric::Gauge(g) => g.clone(),
            _ => panic!("metric '{name}' already registered as another kind"),
        }
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut inner = self.inner.lock().unwrap();
        match inner.entry(name.to_string()).or_insert_with(|| {
            Metric::Histogram(Arc::new(Histogram::default()))
        }) {
            Metric::Histogram(h) => h.clone(),
            _ => panic!("metric '{name}' already registered as another kind"),
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Every instrument's current value as one JSON object.
    pub fn snapshot(&self) -> Json {
        let inner = self.inner.lock().unwrap();
        Json::Obj(
            inner
                .iter()
                .map(|(name, m)| {
                    let v = match m {
                        Metric::Counter(c) => Json::Num(c.get() as f64),
                        Metric::Gauge(g) => Json::Num(g.get()),
                        Metric::Histogram(h) => h.to_json(),
                    };
                    (name.clone(), v)
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let r = MetricsRegistry::new();
        let c = r.counter("serve.dispatches");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // Re-registration shares the instrument.
        assert_eq!(r.counter("serve.dispatches").get(), 5);
        let g = r.gauge("pool.occupancy");
        g.set(0.75);
        assert_eq!(g.get(), 0.75);
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn histogram_buckets_and_percentiles() {
        let h = Histogram::default();
        assert_eq!(h.percentile(50.0), 0.0);
        // 90 fast observations at ~0.1ms, 10 slow at ~100ms.
        for _ in 0..90 {
            h.observe(0.1);
        }
        for _ in 0..10 {
            h.observe(100.0);
        }
        assert_eq!(h.count(), 100);
        let p50 = h.percentile(50.0);
        let p99 = h.percentile(99.0);
        assert!(
            (0.05..0.3).contains(&p50),
            "p50 {p50} must sit in the fast bucket"
        );
        assert!(
            (50.0..300.0).contains(&p99),
            "p99 {p99} must sit in the slow bucket"
        );
        assert!((h.mean_ms() - 10.09).abs() < 0.5, "{}", h.mean_ms());
        // Guards: junk observations are dropped, not panics.
        h.observe(f64::NAN);
        h.observe(-1.0);
        assert_eq!(h.count(), 100);
        let j = h.to_json();
        assert_eq!(j.get("count").unwrap().as_usize(), Some(100));
        assert_eq!(j.get("buckets").unwrap().as_arr().map(|b| b.len()), Some(2));
    }

    #[test]
    fn histogram_extremes_clamp_to_edge_buckets() {
        let h = Histogram::default();
        h.observe(0.0);
        h.observe(1e12);
        assert_eq!(h.count(), 2);
        assert!(h.percentile(0.0) >= 0.0);
        assert!(h.percentile(100.0).is_finite());
    }

    #[test]
    fn histogram_empty_population_answers_zero_everywhere() {
        let h = Histogram::default();
        for p in [0.0, 50.0, 95.0, 100.0, -3.0, 400.0] {
            assert_eq!(h.percentile(p), 0.0);
        }
        assert_eq!(h.mean_ms(), 0.0);
        assert_eq!(h.percentile(f64::NAN), 0.0);
        assert_eq!(h.percentile(f64::INFINITY), 0.0);
    }

    #[test]
    fn histogram_single_sample_stays_in_its_bucket() {
        let h = Histogram::default();
        h.observe(0.5); // bucket [0.256, 0.512) ms
        for p in [0.0, 50.0, 100.0] {
            let v = h.percentile(p);
            assert!(
                (0.256..=0.512).contains(&v),
                "p{p} = {v} escaped the sample's bucket"
            );
        }
        // A junk percentile on a warm histogram still answers 0, not
        // a panic or an arbitrary bucket.
        assert_eq!(h.percentile(f64::NAN), 0.0);
    }

    #[test]
    fn histogram_clock_granularity_durations_bucket_low() {
        // Zero and sub-microsecond durations (clock granularity) land
        // in bucket 0; negatives and NaN are dropped, never bucketed.
        let h = Histogram::default();
        h.observe(0.0);
        h.observe(1e-9);
        h.observe(Histogram::BASE_MS);
        assert_eq!(h.count(), 3);
        let p100 = h.percentile(100.0);
        assert!(
            p100 <= 2.0 * Histogram::BASE_MS,
            "p100 {p100} escaped bucket 0"
        );
        h.observe(-0.0);
        assert_eq!(h.count(), 4, "-0.0 is a valid zero duration");
        h.observe(-1e-9);
        h.observe(f64::NEG_INFINITY);
        assert_eq!(h.count(), 4);
    }

    #[test]
    fn snapshot_renders_every_kind() {
        let r = MetricsRegistry::new();
        r.counter("a.count").add(3);
        r.gauge("b.gauge").set(1.5);
        r.histogram("c.lat").observe(2.0);
        let snap = r.snapshot();
        let text = snap.to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(parsed.get("a.count").unwrap().as_usize(), Some(3));
        assert_eq!(parsed.get("b.gauge").unwrap().as_f64(), Some(1.5));
        assert_eq!(
            parsed.get("c.lat").unwrap().get("count").unwrap().as_usize(),
            Some(1)
        );
    }
}
