//! Online scalability attribution — the layer that turns the span
//! rings and worker tallies of PR 6 into live scalability *diagnosis*.
//!
//! The paper's central question is why an SpMV kernel stops scaling on
//! FT-2000+. Offline it answers with a regression tree over matrix
//! features; the serving engine can do better, because for every
//! executed batch it already holds the raw signal: per-lane kernel
//! busy time ([`crate::exec::ExecPool`] worker tallies), the
//! engine-measured dispatch stages (plan lookup, partition, reduce,
//! autotune observe), and the kernel wall clock. This module
//! decomposes the gap between ideal linear speedup and what the batch
//! actually achieved into counted components:
//!
//! * **load imbalance** — the busiest lane ran longer than the mean
//!   lane (`max - work/threads`): ragged row partitions, the paper's
//!   `job_var` factor made visible per batch;
//! * **dispatch/sync overhead** — time outside useful kernel work:
//!   plan lookup + partition + reduce + autotune-observe on the
//!   dispatcher, plus the latch tail (`wall - max_lane`) where every
//!   lane waited for the join;
//! * **memory-bound residual** — the remainder of the gap. On the
//!   replay cost model this is exactly the bandwidth-saturation loss
//!   (`eff = min(threads, sat_threads)` in
//!   [`crate::service::CostModel`]); on live measurements memory
//!   stalls inflate each lane's busy time instead, so the per-batch
//!   residual stays near zero and the bandwidth ceiling surfaces as
//!   the *efficiency curve* flattening — the paper's speedup plateau.
//!
//! Components are aggregated per matrix fingerprint into online
//! efficiency curves (effective threads → speedup estimate, where
//! speedup = serial-equivalent work / kernel wall) with knee detection
//! mirroring the autotune ladder's plateau hunt
//! ([`crate::autotune::ladder::knee_index`]): the fewest threads whose
//! speedup is within tolerance of the best observed.
//!
//! The profiler is always on and allocation-free in steady state: the
//! per-batch record path is a mutex + BTreeMap probe + float adds
//! (`tests/alloc.rs` pins it), with map nodes allocated only the first
//! time a (fingerprint, thread-count) pair is seen — the same warmup
//! discipline as serving telemetry. Snapshots export under the
//! versioned `ft2000.scaling.v1` schema; [`compare`] diffs two
//! snapshots into counted [`CheckReport`] findings (efficiency drop,
//! knee shift, stage-share drift, queue-wait SLO burn) for the
//! `ft2000-spmv obs-report` CI gate.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::check::{CheckReport, Finding};
use crate::util::json::Json;
use crate::util::table::Table;

/// Fixed bound on the per-dispatch lane-snapshot buffers the serve
/// path keeps on its stack (dispatcher lane + up to 64 workers — the
/// FT-2000+ has 64 cores). Pools wider than this degrade gracefully:
/// extra lanes are simply not attributed.
pub const MAX_LANES: usize = 65;

/// Plateau tolerance for knee detection — mirrors the autotune
/// ladder's default (`AutotuneConfig::knee_tol`): the knee is the
/// fewest effective threads whose speedup is within 5% of the best.
pub const KNEE_TOL: f64 = 0.05;

/// One batch's decomposition of the gap to ideal linear speedup.
/// Constructed by [`GapComponents::from_parts`] so the accounting
/// identity `gap = imbalance + overhead + residual` holds exactly by
/// construction (pinned by test on the deterministic replay).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct GapComponents {
    /// Serial-equivalent useful work: sum of lane kernel busy time
    /// (live) or the cost model's serial kernel term (replay).
    pub work_s: f64,
    /// Kernel wall time (the parallel region).
    pub kernel_s: f64,
    /// What the batch actually cost: kernel wall + dispatch overhead.
    pub observed_s: f64,
    /// `work_s / threads` — the linear-speedup target.
    pub ideal_s: f64,
    /// `observed_s - ideal_s`, split exactly into the three below.
    pub gap_s: f64,
    /// Busiest lane minus mean lane kernel time.
    pub imbalance_s: f64,
    /// Dispatch stages outside the kernel + the latch tail inside it.
    pub overhead_s: f64,
    /// The unattributed remainder (model: bandwidth saturation).
    pub residual_s: f64,
    /// Effective parallel speedup estimate: `work_s / kernel_s`.
    pub speedup: f64,
    /// Whether per-lane tallies backed this sample (false for
    /// spawn-mode engines, where work degrades to the wall clock and
    /// the speedup estimate to 1).
    pub lane_data: bool,
}

impl GapComponents {
    /// Assemble the decomposition from its measured (or modeled)
    /// parts. `dispatch_s` is stage time outside the kernel wall;
    /// `latch_s` is join-wait inside it. The residual absorbs what
    /// imbalance and overhead do not explain, so the components always
    /// sum to the gap.
    pub fn from_parts(
        threads: usize,
        work_s: f64,
        kernel_s: f64,
        dispatch_s: f64,
        imbalance_s: f64,
        latch_s: f64,
        lane_data: bool,
    ) -> GapComponents {
        let th = threads.max(1) as f64;
        let observed_s = kernel_s + dispatch_s;
        let ideal_s = work_s / th;
        let gap_s = observed_s - ideal_s;
        let overhead_s = dispatch_s + latch_s;
        let residual_s = gap_s - imbalance_s - overhead_s;
        let speedup = if kernel_s > 0.0 { work_s / kernel_s } else { th };
        GapComponents {
            work_s,
            kernel_s,
            observed_s,
            ideal_s,
            gap_s,
            imbalance_s,
            overhead_s,
            residual_s,
            speedup,
            lane_data,
        }
    }

    /// Decomposition for a live pooled dispatch from the per-lane
    /// busy-time deltas around the kernel. Without lane data (spawn
    /// mode) the work estimate degrades to the wall clock: imbalance
    /// and latch are unobservable and the gap is all dispatch
    /// overhead.
    pub fn from_executed(
        threads: usize,
        kernel_s: f64,
        busy_max_s: f64,
        busy_sum_s: f64,
        dispatch_s: f64,
        lane_data: bool,
    ) -> GapComponents {
        if !lane_data || busy_sum_s <= 0.0 {
            return Self::from_parts(
                threads, kernel_s, kernel_s, dispatch_s, 0.0, 0.0, false,
            );
        }
        let mean_s = busy_sum_s / threads.max(1) as f64;
        let imbalance_s = (busy_max_s - mean_s).max(0.0);
        let latch_s = (kernel_s - busy_max_s).max(0.0);
        Self::from_parts(
            threads,
            busy_sum_s,
            kernel_s,
            dispatch_s,
            imbalance_s,
            latch_s,
            true,
        )
    }

    /// Fold post-hoc dispatcher time (e.g. the autotune-observe stage,
    /// measured after the tuner consumed this batch's attribution)
    /// into the overhead component. Observed, gap, and overhead all
    /// grow by `extra_s`; the residual is untouched, so the accounting
    /// identity survives.
    pub fn with_extra_overhead(mut self, extra_s: f64) -> GapComponents {
        let extra_s = extra_s.max(0.0);
        self.observed_s += extra_s;
        self.gap_s += extra_s;
        self.overhead_s += extra_s;
        self
    }
}

/// Aggregated component sums — one per matrix plus one grand total.
#[derive(Clone, Copy, Debug, Default)]
pub struct GapTotals {
    pub batches: u64,
    pub requests: u64,
    pub work_s: f64,
    pub kernel_s: f64,
    pub observed_s: f64,
    pub ideal_s: f64,
    pub gap_s: f64,
    pub imbalance_s: f64,
    pub overhead_s: f64,
    pub residual_s: f64,
}

impl GapTotals {
    fn add(&mut self, batch: usize, c: &GapComponents) {
        self.batches += 1;
        self.requests += batch as u64;
        self.work_s += c.work_s;
        self.kernel_s += c.kernel_s;
        self.observed_s += c.observed_s;
        self.ideal_s += c.ideal_s;
        self.gap_s += c.gap_s;
        self.imbalance_s += c.imbalance_s;
        self.overhead_s += c.overhead_s;
        self.residual_s += c.residual_s;
    }

    fn merge(&mut self, o: &GapTotals) {
        self.batches += o.batches;
        self.requests += o.requests;
        self.work_s += o.work_s;
        self.kernel_s += o.kernel_s;
        self.observed_s += o.observed_s;
        self.ideal_s += o.ideal_s;
        self.gap_s += o.gap_s;
        self.imbalance_s += o.imbalance_s;
        self.overhead_s += o.overhead_s;
        self.residual_s += o.residual_s;
    }

    /// Share of the gap each component explains, clamped to [0, 1]
    /// (zero when there is no gap to attribute).
    pub fn shares(&self) -> (f64, f64, f64) {
        if self.gap_s <= 0.0 {
            return (0.0, 0.0, 0.0);
        }
        let share = |c: f64| (c / self.gap_s).clamp(0.0, 1.0);
        (
            share(self.imbalance_s),
            share(self.overhead_s),
            share(self.residual_s),
        )
    }

    fn to_json(self) -> Json {
        let (imb, ovh, res) = self.shares();
        Json::Obj(
            [
                ("batches", self.batches as f64),
                ("requests", self.requests as f64),
                ("work_s", self.work_s),
                ("kernel_s", self.kernel_s),
                ("observed_s", self.observed_s),
                ("ideal_s", self.ideal_s),
                ("gap_s", self.gap_s),
                ("imbalance_s", self.imbalance_s),
                ("overhead_s", self.overhead_s),
                ("residual_s", self.residual_s),
                ("imbalance_share", imb),
                ("overhead_share", ovh),
                ("residual_share", res),
            ]
            .into_iter()
            .map(|(k, v)| (k.to_string(), Json::Num(v)))
            .collect(),
        )
    }
}

/// One point of a matrix's efficiency curve: all batches that ran at
/// this effective thread count.
#[derive(Clone, Copy, Debug, Default)]
struct CurveCell {
    batches: u64,
    work_s: f64,
    kernel_s: f64,
}

impl CurveCell {
    fn speedup(&self) -> f64 {
        if self.kernel_s > 0.0 {
            self.work_s / self.kernel_s
        } else {
            0.0
        }
    }
}

#[derive(Default)]
struct MatAgg {
    totals: GapTotals,
    /// effective threads -> accumulated curve point.
    curve: BTreeMap<usize, CurveCell>,
}

impl MatAgg {
    /// The speedup-plateau knee: the fewest effective threads whose
    /// mean speedup is within `tol` of the best bucket — the same
    /// fewest-resources-on-the-plateau hunt as
    /// [`crate::autotune::ladder::knee_index`], over measured curves
    /// instead of ladder arms.
    fn knee_threads(&self, tol: f64) -> Option<usize> {
        let best = self
            .curve
            .values()
            .map(CurveCell::speedup)
            .fold(f64::NEG_INFINITY, f64::max);
        if !best.is_finite() || best <= 0.0 {
            return None;
        }
        self.curve
            .iter()
            .find(|(_, c)| c.speedup() >= best * (1.0 - tol))
            .map(|(&th, _)| th)
    }
}

#[derive(Default)]
struct ProfilerState {
    total: GapTotals,
    by_matrix: BTreeMap<u64, MatAgg>,
}

/// Queue-wait summary the engine folds into the scalability snapshot
/// (the obs-report SLO-burn gate reads it): serving telemetry owns the
/// digest, this is the flattened view.
#[derive(Clone, Copy, Debug, Default)]
pub struct QueueWaitSummary {
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub mean_ms: f64,
    pub count: u64,
}

/// The always-on scalability profiler one [`crate::service::ServeEngine`]
/// carries. Interior-mutable (one mutex) so the dispatch path records
/// through `&self`; see the module docs for the accounting model.
pub struct ScalingProfiler {
    enabled: bool,
    inner: Mutex<ProfilerState>,
}

impl Default for ScalingProfiler {
    fn default() -> Self {
        Self::new()
    }
}

impl ScalingProfiler {
    pub fn new() -> ScalingProfiler {
        ScalingProfiler {
            enabled: true,
            inner: Mutex::new(ProfilerState::default()),
        }
    }

    /// Flip attribution off (A/B baselines in the obs bench section).
    /// Serving engines leave it on — the point of the profiler is that
    /// scalability data is always being collected.
    pub fn set_enabled(&mut self, on: bool) {
        self.enabled = on;
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, ProfilerState> {
        self.inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Record one batch's decomposition. Alloc-free once this
    /// (fingerprint, threads) pair has been seen (steady state); the
    /// first sighting allocates the map nodes, like telemetry warmup.
    pub fn record(
        &self,
        fingerprint: u64,
        threads: usize,
        batch: usize,
        c: &GapComponents,
    ) {
        if !self.enabled {
            return;
        }
        let mut st = self.lock();
        st.total.add(batch, c);
        let mat = st.by_matrix.entry(fingerprint).or_default();
        mat.totals.add(batch, c);
        let cell = mat.curve.entry(threads.max(1)).or_default();
        cell.batches += 1;
        cell.work_s += c.work_s;
        cell.kernel_s += c.kernel_s;
    }

    /// Batches attributed so far (all matrices).
    pub fn batches(&self) -> u64 {
        self.lock().total.batches
    }

    /// Grand-total component sums.
    pub fn totals(&self) -> GapTotals {
        self.lock().total
    }

    /// Fold another profiler's aggregates into this one — the sharded
    /// roll-up ([`crate::service::ShardedServer`] merges its per-shard
    /// engines' profilers into one snapshot).
    pub fn merge_from(&self, other: &ScalingProfiler) {
        let o = other.lock();
        let mut st = self.lock();
        st.total.merge(&o.total);
        for (fp, mat) in &o.by_matrix {
            let dst = st.by_matrix.entry(*fp).or_default();
            dst.totals.merge(&mat.totals);
            for (th, cell) in &mat.curve {
                let d = dst.curve.entry(*th).or_default();
                d.batches += cell.batches;
                d.work_s += cell.work_s;
                d.kernel_s += cell.kernel_s;
            }
        }
    }

    /// The versioned `ft2000.scaling.v1` snapshot. Documented keys
    /// (golden-pinned by `tests/obs.rs`):
    ///
    /// * `schema`, `batches`
    /// * `gap` — grand-total [`GapTotals`] fields + `*_share`s
    /// * `queue_wait_ms` — `p50_ms`/`p95_ms`/`mean_ms`/`count`
    /// * `matrices[]` — `fingerprint` (hex), per-matrix `gap` object,
    ///   `efficiency[]` curve (`threads`/`batches`/`speedup`/
    ///   `efficiency`), `knee_threads` (null until measurable)
    pub fn snapshot(&self, qw: &QueueWaitSummary) -> Json {
        let st = self.lock();
        let mut mats = Vec::new();
        for (fp, mat) in &st.by_matrix {
            let curve: Vec<Json> = mat
                .curve
                .iter()
                .map(|(&th, cell)| {
                    let sp = cell.speedup();
                    Json::Obj(
                        [
                            ("threads", th as f64),
                            ("batches", cell.batches as f64),
                            ("speedup", sp),
                            ("efficiency", sp / th.max(1) as f64),
                        ]
                        .into_iter()
                        .map(|(k, v)| (k.to_string(), Json::Num(v)))
                        .collect(),
                    )
                })
                .collect();
            let mut obj = BTreeMap::new();
            obj.insert(
                "fingerprint".to_string(),
                Json::Str(format!("{fp:016x}")),
            );
            obj.insert("gap".to_string(), mat.totals.to_json());
            obj.insert("efficiency".to_string(), Json::Arr(curve));
            obj.insert(
                "knee_threads".to_string(),
                mat.knee_threads(KNEE_TOL)
                    .map_or(Json::Null, |k| Json::Num(k as f64)),
            );
            mats.push(Json::Obj(obj));
        }
        let mut obj = BTreeMap::new();
        obj.insert(
            "schema".to_string(),
            Json::Str("ft2000.scaling.v1".to_string()),
        );
        obj.insert("batches".to_string(), Json::Num(st.total.batches as f64));
        obj.insert("gap".to_string(), st.total.to_json());
        obj.insert(
            "queue_wait_ms".to_string(),
            Json::Obj(
                [
                    ("p50_ms", qw.p50_ms),
                    ("p95_ms", qw.p95_ms),
                    ("mean_ms", qw.mean_ms),
                    ("count", qw.count as f64),
                ]
                .into_iter()
                .map(|(k, v)| (k.to_string(), Json::Num(v)))
                .collect(),
            ),
        );
        obj.insert("matrices".to_string(), Json::Arr(mats));
        Json::Obj(obj)
    }

    /// The rendered attribution table: one row per matrix, the knee,
    /// speedup at the knee, and where the gap went.
    pub fn table(&self) -> Table {
        let st = self.lock();
        let mut t = Table::new(
            "scalability attribution (gap to linear speedup)",
            &[
                "fingerprint",
                "batches",
                "knee",
                "speedup@knee",
                "gap ms",
                "imbalance",
                "overhead",
                "residual",
            ],
        );
        let pct = |x: f64| format!("{:.1}%", x * 100.0);
        for (fp, mat) in &st.by_matrix {
            let knee = mat.knee_threads(KNEE_TOL);
            let sp = knee
                .and_then(|k| mat.curve.get(&k))
                .map_or(0.0, CurveCell::speedup);
            let (imb, ovh, res) = mat.totals.shares();
            t.row(vec![
                format!("{fp:016x}"),
                mat.totals.batches.to_string(),
                knee.map_or("-".to_string(), |k| k.to_string()),
                format!("{sp:.2}"),
                format!("{:.3}", mat.totals.gap_s * 1e3),
                pct(imb),
                pct(ovh),
                pct(res),
            ]);
        }
        t
    }
}

/// Thresholds for [`compare`] — the obs-report regression gate.
#[derive(Clone, Copy, Debug)]
pub struct CompareThresholds {
    /// Relative per-matrix peak-speedup drop that counts as an
    /// efficiency regression (0.10 = 10%).
    pub efficiency_drop: f64,
    /// Knee shift (in threads, either direction) that counts as a
    /// scalability-shape regression.
    pub knee_shift: usize,
    /// Absolute drift in a gap component's share of the total gap.
    pub share_drift: f64,
    /// Absolute queue-wait p95 SLO in ms. `None` derives a burn
    /// threshold from the baseline: `2 * baseline_p95 + 1ms`.
    pub queue_p95_ms: Option<f64>,
}

impl Default for CompareThresholds {
    fn default() -> Self {
        CompareThresholds {
            efficiency_drop: 0.10,
            knee_shift: 2,
            share_drift: 0.10,
            queue_p95_ms: None,
        }
    }
}

fn num(doc: &Json, path: &[&str]) -> Option<f64> {
    let mut cur = doc;
    for k in path {
        cur = cur.get(k)?;
    }
    cur.as_f64()
}

fn check(
    report: &mut CheckReport,
    ok: bool,
    subject: String,
    invariant: &'static str,
    detail: impl FnOnce() -> String,
) {
    report.checked += 1;
    if !ok {
        report.findings.push(Finding {
            subject,
            invariant,
            detail: detail(),
        });
    }
}

/// Diff two `ft2000.scaling.v1` snapshots into counted regression
/// findings. Identical documents always compare clean; every finding
/// names the matrix (or the global surface) it fired on. The four
/// finding families are the ones a scalability SLO cares about:
/// peak-efficiency drop, knee shift, gap-composition drift, and
/// queue-wait SLO burn.
pub fn compare(
    baseline: &Json,
    current: &Json,
    th: &CompareThresholds,
) -> CheckReport {
    let mut report = CheckReport::new();
    for (name, doc) in [("baseline", baseline), ("current", current)] {
        check(
            &mut report,
            doc.get("schema").and_then(Json::as_str)
                == Some("ft2000.scaling.v1"),
            name.to_string(),
            "scaling-schema",
            || {
                format!(
                    "expected schema ft2000.scaling.v1, got {:?}",
                    doc.get("schema")
                )
            },
        );
    }
    if !report.is_clean() {
        return report;
    }

    // Index both matrix lists by fingerprint.
    let index = |doc: &Json| -> BTreeMap<String, Json> {
        doc.get("matrices")
            .and_then(Json::as_arr)
            .map(|arr| {
                arr.iter()
                    .filter_map(|m| {
                        let fp = m.get("fingerprint")?.as_str()?.to_string();
                        Some((fp, m.clone()))
                    })
                    .collect()
            })
            .unwrap_or_default()
    };
    let base_mats = index(baseline);
    let cur_mats = index(current);

    for (fp, b) in &base_mats {
        let Some(c) = cur_mats.get(fp) else {
            // A matrix disappearing from the snapshot is a coverage
            // change, not a scalability regression — skip silently
            // (replays over different corpora are comparable on the
            // shared part).
            continue;
        };
        // Peak speedup across the efficiency curve.
        let peak = |m: &Json| -> f64 {
            m.get("efficiency")
                .and_then(Json::as_arr)
                .map(|arr| {
                    arr.iter()
                        .filter_map(|p| num(p, &["speedup"]))
                        .fold(0.0, f64::max)
                })
                .unwrap_or(0.0)
        };
        let (pb, pc) = (peak(b), peak(c));
        check(
            &mut report,
            pb <= 0.0 || pc >= pb * (1.0 - th.efficiency_drop),
            format!("matrix {fp}"),
            "efficiency-drop",
            || {
                format!(
                    "peak speedup fell {pb:.3} -> {pc:.3} \
                     (> {:.0}% drop)",
                    th.efficiency_drop * 100.0
                )
            },
        );
        let knee = |m: &Json| num(m, &["knee_threads"]);
        if let (Some(kb), Some(kc)) = (knee(b), knee(c)) {
            let shift = (kb - kc).abs();
            check(
                &mut report,
                shift < th.knee_shift as f64,
                format!("matrix {fp}"),
                "knee-shift",
                || {
                    format!(
                        "speedup knee moved {kb:.0} -> {kc:.0} threads \
                         (>= {} shift)",
                        th.knee_shift
                    )
                },
            );
        }
    }

    // Gap-composition drift on the grand total.
    for share in ["imbalance_share", "overhead_share", "residual_share"] {
        let (sb, sc) = (
            num(baseline, &["gap", share]).unwrap_or(0.0),
            num(current, &["gap", share]).unwrap_or(0.0),
        );
        check(
            &mut report,
            (sb - sc).abs() <= th.share_drift,
            "gap composition".to_string(),
            "stage-share-drift",
            || {
                format!(
                    "{share} drifted {:.1}% -> {:.1}% \
                     (> {:.0} point tolerance)",
                    sb * 100.0,
                    sc * 100.0,
                    th.share_drift * 100.0
                )
            },
        );
    }

    // Queue-wait SLO burn.
    let base_p95 = num(baseline, &["queue_wait_ms", "p95_ms"]).unwrap_or(0.0);
    let cur_p95 = num(current, &["queue_wait_ms", "p95_ms"]).unwrap_or(0.0);
    let slo = th.queue_p95_ms.unwrap_or(2.0 * base_p95 + 1.0);
    check(
        &mut report,
        cur_p95 <= slo,
        "queue wait".to_string(),
        "queue-slo-burn",
        || {
            format!(
                "p95 queue wait {cur_p95:.3} ms exceeds SLO {slo:.3} ms \
                 (baseline p95 {base_p95:.3} ms)"
            )
        },
    );
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn components_sum_exactly_from_parts() {
        let c = GapComponents::from_parts(
            8, 0.8, 0.13, 0.002, 0.015, 0.001, true,
        );
        let sum = c.imbalance_s + c.overhead_s + c.residual_s;
        assert!((sum - c.gap_s).abs() < 1e-12, "{sum} != {}", c.gap_s);
        assert!((c.observed_s - (0.13 + 0.002)).abs() < 1e-15);
        assert!((c.ideal_s - 0.1).abs() < 1e-15);
        // Post-hoc overhead keeps the identity.
        let c2 = c.with_extra_overhead(0.003);
        let sum2 = c2.imbalance_s + c2.overhead_s + c2.residual_s;
        assert!((sum2 - c2.gap_s).abs() < 1e-12);
        assert_eq!(c2.residual_s, c.residual_s);
    }

    #[test]
    fn executed_decomposition_attributes_imbalance_and_latch() {
        // 4 threads, lanes busy 40/30/20/10 ms, wall 45 ms, 2 ms
        // dispatch: mean lane = 25 ms, imbalance = 15 ms, latch = 5 ms.
        let c = GapComponents::from_executed(
            4, 0.045, 0.040, 0.100, 0.002, true,
        );
        assert!((c.work_s - 0.100).abs() < 1e-15);
        assert!((c.imbalance_s - 0.015).abs() < 1e-12);
        assert!((c.overhead_s - 0.007).abs() < 1e-12);
        let sum = c.imbalance_s + c.overhead_s + c.residual_s;
        assert!((sum - c.gap_s).abs() < 1e-12);
        // Speedup estimate: 100 ms work in a 45 ms wall.
        assert!((c.speedup - 100.0 / 45.0).abs() < 1e-9);
    }

    #[test]
    fn executed_without_lane_data_degrades_to_overhead_only() {
        let c =
            GapComponents::from_executed(4, 0.010, 0.0, 0.0, 0.001, false);
        assert!(!c.lane_data);
        assert!((c.speedup - 1.0).abs() < 1e-12);
        assert!((c.imbalance_s).abs() < 1e-15);
        let sum = c.imbalance_s + c.overhead_s + c.residual_s;
        assert!((sum - c.gap_s).abs() < 1e-12);
    }

    fn record_curve(p: &ScalingProfiler, fp: u64, th: usize, speedup: f64) {
        // One batch whose work/wall ratio is exactly `speedup`.
        let wall = 0.010;
        let c = GapComponents::from_parts(
            th,
            wall * speedup,
            wall,
            0.0,
            0.0,
            0.0,
            true,
        );
        p.record(fp, th, 1, &c);
    }

    #[test]
    fn knee_mirrors_ladder_plateau_hunt() {
        let p = ScalingProfiler::new();
        // Speedup plateaus at 4 threads: 1.0, 3.9, 4.0, 4.05.
        record_curve(&p, 7, 1, 1.0);
        record_curve(&p, 7, 2, 2.0);
        record_curve(&p, 7, 4, 3.9);
        record_curve(&p, 7, 8, 4.0);
        record_curve(&p, 7, 16, 4.05);
        let st = p.lock();
        let knee = st.by_matrix[&7].knee_threads(KNEE_TOL);
        // 3.9 >= 4.05 * 0.95 — four threads sit on the plateau.
        assert_eq!(knee, Some(4));
    }

    #[test]
    fn snapshot_and_merge_aggregate_by_fingerprint() {
        let a = ScalingProfiler::new();
        let b = ScalingProfiler::new();
        record_curve(&a, 1, 4, 3.0);
        record_curve(&b, 1, 4, 3.0);
        record_curve(&b, 2, 8, 5.0);
        a.merge_from(&b);
        assert_eq!(a.batches(), 3);
        let snap = a.snapshot(&QueueWaitSummary::default());
        let mats = snap.get("matrices").and_then(Json::as_arr).unwrap();
        assert_eq!(mats.len(), 2);
        let eff = mats[0].get("efficiency").and_then(Json::as_arr).unwrap();
        assert_eq!(
            eff[0].get("batches").and_then(Json::as_f64),
            Some(2.0)
        );
        assert_eq!(
            eff[0].get("speedup").and_then(Json::as_f64),
            Some(3.0)
        );
    }

    #[test]
    fn disabled_profiler_records_nothing() {
        let mut p = ScalingProfiler::new();
        p.set_enabled(false);
        record_curve(&p, 1, 4, 3.0);
        assert_eq!(p.batches(), 0);
    }

    #[test]
    fn compare_is_clean_on_identical_snapshots() {
        let p = ScalingProfiler::new();
        record_curve(&p, 1, 4, 3.0);
        record_curve(&p, 1, 8, 3.2);
        let qw = QueueWaitSummary {
            p50_ms: 0.1,
            p95_ms: 0.4,
            mean_ms: 0.15,
            count: 10,
        };
        let snap = p.snapshot(&qw);
        let report =
            compare(&snap, &snap, &CompareThresholds::default());
        assert!(report.is_clean(), "{report}");
        assert!(report.checked >= 5);
    }

    #[test]
    fn compare_counts_every_regression_family() {
        let p = ScalingProfiler::new();
        record_curve(&p, 1, 2, 2.0);
        record_curve(&p, 1, 4, 4.0);
        let qw = QueueWaitSummary {
            p95_ms: 0.4,
            ..QueueWaitSummary::default()
        };
        let base = p.snapshot(&qw);

        let bad = ScalingProfiler::new();
        // Speedup halved, knee pushed out, queue wait burned.
        record_curve(&bad, 1, 2, 1.0);
        record_curve(&bad, 1, 4, 1.1);
        record_curve(&bad, 1, 16, 2.0);
        let qw_bad = QueueWaitSummary {
            p95_ms: 40.0,
            ..QueueWaitSummary::default()
        };
        let cur = bad.snapshot(&qw_bad);
        let report = compare(&base, &cur, &CompareThresholds::default());
        assert!(!report.is_clean());
        let inv: Vec<&str> =
            report.findings.iter().map(|f| f.invariant).collect();
        assert!(inv.contains(&"efficiency-drop"), "{inv:?}");
        assert!(inv.contains(&"knee-shift"), "{inv:?}");
        assert!(inv.contains(&"queue-slo-burn"), "{inv:?}");
    }

    #[test]
    fn compare_rejects_wrong_schema() {
        let doc = Json::Obj(
            [("schema".to_string(), Json::Str("nope".to_string()))]
                .into_iter()
                .collect(),
        );
        let report =
            compare(&doc, &doc, &CompareThresholds::default());
        assert_eq!(report.findings.len(), 2);
        assert_eq!(report.findings[0].invariant, "scaling-schema");
    }
}
