//! Structural invariant verifier — the installation contract for
//! every sparse format, partition, and plan in the serving engine.
//!
//! The paper's characterization (and the autotune/mlmodel dataset
//! built on top of it) is only as trustworthy as the structures
//! feeding it labels: a mis-covered partition or a corrupted SELL
//! permutation silently poisons served results long before anything
//! panics. This module makes the implicit invariants explicit and
//! machine-checkable, at three costs:
//!
//! * **Deep checks** (`check_csr`, `check_csr5_vs_csr`, `check_plan`,
//!   ...) — O(nnz) sweeps producing a [`CheckReport`] with one
//!   [`Finding`] per violated invariant. Used at registry
//!   registration, by the `ft2000-spmv check` CLI sweep, and by the
//!   corruption property tests.
//! * **[`quick_plan_check`]** — an O(slots), allocation-free subset
//!   run on the serve path when `PlanConfig::validate` is set
//!   (default: debug builds). It checks the *cross-structure
//!   agreements* a cached plan could violate (family, parameters,
//!   coverage totals), not per-nonzero content.
//! * **`check::interleave`** — a deterministic schedule-permutation
//!   harness for the lock-free executor pool and trace rings.
//!
//! Checks never panic on corrupt input: every content scan is gated
//! on the structural checks it depends on (e.g. `csr.row()` is only
//! called once `ptr` is known monotone and in-bounds).

pub mod hb;
pub mod interleave;

use crate::exec;
use crate::sched::{Partition, Schedule};
use crate::service::plan::{Plan, PlanCache, PlannedFormat};
use crate::sparse::sell::normalize_sigma;
use crate::sparse::{Coo, Csr, Csr5, Dia, Ell, Hyb, SellCSigma};

/// One violated invariant: which structure, which invariant, and the
/// first offending site (checks report the first violation per
/// invariant, not every occurrence — a corrupt 1M-nnz array should
/// produce one line, not a million).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    /// What was being checked (matrix name, "plan", ...).
    pub subject: String,
    /// Stable invariant tag, e.g. `ptr-monotone`, `perm-permutation`.
    pub invariant: &'static str,
    /// Human-readable first-offender detail.
    pub detail: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}: {}", self.subject, self.invariant, self.detail)
    }
}

/// Outcome of a verification pass: the findings plus how many
/// invariants were evaluated (so "clean" is distinguishable from
/// "checked nothing").
#[derive(Clone, Debug, Default)]
pub struct CheckReport {
    pub findings: Vec<Finding>,
    pub checked: usize,
}

impl CheckReport {
    pub fn new() -> Self {
        CheckReport::default()
    }

    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Fold another report into this one.
    pub fn merge(&mut self, other: CheckReport) {
        self.checked += other.checked;
        self.findings.extend(other.findings);
    }

    /// Record one invariant evaluation; on failure the (lazily
    /// rendered) detail becomes a [`Finding`]. Returns `ok` so
    /// callers can gate dependent checks.
    fn check(
        &mut self,
        ok: bool,
        subject: &str,
        invariant: &'static str,
        detail: impl FnOnce() -> String,
    ) -> bool {
        self.checked += 1;
        if !ok {
            self.findings.push(Finding {
                subject: subject.to_string(),
                invariant,
                detail: detail(),
            });
        }
        ok
    }
}

impl std::fmt::Display for CheckReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_clean() {
            return write!(f, "clean ({} invariants)", self.checked);
        }
        writeln!(
            f,
            "{} finding(s) over {} invariants:",
            self.findings.len(),
            self.checked
        )?;
        for fd in &self.findings {
            writeln!(f, "  {fd}")?;
        }
        Ok(())
    }
}

/// Shared row-pointer discipline (CSR and the CSR5 copy of it):
/// length `n_rows + 1`, starts at 0, non-decreasing, ends at `nnz`.
/// Returns whether `ptr` is safe to index rows through.
fn check_row_ptr(
    r: &mut CheckReport,
    subject: &str,
    ptr: &[usize],
    n_rows: usize,
    nnz: usize,
) -> bool {
    if !r.check(ptr.len() == n_rows + 1, subject, "ptr-len", || {
        format!("ptr length {} != n_rows + 1 = {}", ptr.len(), n_rows + 1)
    }) {
        return false;
    }
    let start = r.check(ptr[0] == 0, subject, "ptr-start", || {
        format!("ptr[0] = {} != 0", ptr[0])
    });
    let mono = r.check(
        ptr.windows(2).all(|w| w[0] <= w[1]),
        subject,
        "ptr-monotone",
        || {
            let i = ptr.windows(2).position(|w| w[0] > w[1]).unwrap_or(0);
            format!("ptr[{}] = {} > ptr[{}] = {}", i, ptr[i], i + 1, ptr[i + 1])
        },
    );
    let end = r.check(ptr[n_rows] == nnz, subject, "ptr-end", || {
        format!("ptr[n_rows] = {} != nnz = {}", ptr[n_rows], nnz)
    });
    start && mono && end
}

/// Exactly-once row coverage for a `Rows`-shaped slot list (shared by
/// the single-vector `Partition::Rows` check and the memoized SpMM
/// row partition of every plan).
fn check_rows_cover(
    r: &mut CheckReport,
    subject: &str,
    invariant: &'static str,
    per_thread: &[Vec<(usize, usize)>],
    n_rows: usize,
) {
    let mut covered = vec![false; n_rows];
    for (slot, ranges) in per_thread.iter().enumerate() {
        for &(r0, r1) in ranges {
            if !r.check(r0 <= r1 && r1 <= n_rows, subject, invariant, || {
                format!("slot {slot}: bad range ({r0},{r1}) of {n_rows} rows")
            }) {
                return;
            }
            for row in r0..r1 {
                if covered[row] {
                    r.check(false, subject, invariant, || {
                        format!("row {row} covered twice (slot {slot})")
                    });
                    return;
                }
                covered[row] = true;
            }
        }
    }
    r.check(
        covered.iter().all(|&c| c),
        subject,
        invariant,
        || {
            let row = covered.iter().position(|&c| !c).unwrap_or(0);
            format!("row {row} uncovered")
        },
    );
}

/// CSR: row pointer discipline, in-bounds strictly-increasing columns
/// per row, finite values.
pub fn check_csr(subject: &str, a: &Csr) -> CheckReport {
    let mut r = CheckReport::new();
    let nnz = a.data.len();
    r.check(a.indices.len() == nnz, subject, "arrays-aligned", || {
        format!("indices len {} != data len {}", a.indices.len(), nnz)
    });
    let ptr_ok = check_row_ptr(&mut r, subject, &a.ptr, a.n_rows, nnz);
    r.check(
        a.indices.iter().all(|&c| (c as usize) < a.n_cols),
        subject,
        "col-bounds",
        || {
            let i = a
                .indices
                .iter()
                .position(|&c| (c as usize) >= a.n_cols)
                .unwrap_or(0);
            format!(
                "nonzero {i}: col {} >= n_cols {}",
                a.indices[i], a.n_cols
            )
        },
    );
    r.check(
        a.data.iter().all(|v| v.is_finite()),
        subject,
        "val-finite",
        || {
            let i =
                a.data.iter().position(|v| !v.is_finite()).unwrap_or(0);
            format!("nonzero {i}: value {} not finite", a.data[i])
        },
    );
    if ptr_ok && a.indices.len() == nnz {
        let sorted = (0..a.n_rows).find_map(|row| {
            let cols = &a.indices[a.ptr[row]..a.ptr[row + 1]];
            cols.windows(2)
                .any(|w| w[0] >= w[1])
                .then_some(row)
        });
        r.check(sorted.is_none(), subject, "col-sorted", || {
            format!(
                "row {}: columns not strictly increasing",
                sorted.unwrap_or(0)
            )
        });
    }
    r
}

/// COO: aligned parallel arrays, in-bounds coordinates, finite values.
pub fn check_coo(subject: &str, a: &Coo) -> CheckReport {
    let mut r = CheckReport::new();
    let n = a.vals.len();
    let aligned = r.check(
        a.rows.len() == n && a.cols.len() == n,
        subject,
        "arrays-aligned",
        || {
            format!(
                "rows/cols/vals lengths {}/{}/{}",
                a.rows.len(),
                a.cols.len(),
                n
            )
        },
    );
    if !aligned {
        return r;
    }
    r.check(
        a.rows.iter().all(|&x| (x as usize) < a.n_rows),
        subject,
        "row-bounds",
        || {
            let i = a
                .rows
                .iter()
                .position(|&x| (x as usize) >= a.n_rows)
                .unwrap_or(0);
            format!("entry {i}: row {} >= n_rows {}", a.rows[i], a.n_rows)
        },
    );
    r.check(
        a.cols.iter().all(|&x| (x as usize) < a.n_cols),
        subject,
        "col-bounds",
        || {
            let i = a
                .cols
                .iter()
                .position(|&x| (x as usize) >= a.n_cols)
                .unwrap_or(0);
            format!("entry {i}: col {} >= n_cols {}", a.cols[i], a.n_cols)
        },
    );
    r.check(
        a.vals.iter().all(|v| v.is_finite()),
        subject,
        "val-finite",
        || {
            let i =
                a.vals.iter().position(|v| !v.is_finite()).unwrap_or(0);
            format!("entry {i}: value {} not finite", a.vals[i])
        },
    );
    r
}

/// ELL: `[n_rows][k]` layout sizes, in-bounds columns, finite values.
pub fn check_ell(subject: &str, e: &Ell) -> CheckReport {
    let mut r = CheckReport::new();
    let want = e.n_rows * e.k;
    let sized = r.check(
        e.cols.len() == want && e.data.len() == want,
        subject,
        "layout-size",
        || {
            format!(
                "cols/data lengths {}/{} != n_rows*k = {}",
                e.cols.len(),
                e.data.len(),
                want
            )
        },
    );
    if !sized {
        return r;
    }
    r.check(
        e.cols.iter().all(|&c| (c as usize) < e.n_cols.max(1)),
        subject,
        "col-bounds",
        || {
            let i = e
                .cols
                .iter()
                .position(|&c| (c as usize) >= e.n_cols.max(1))
                .unwrap_or(0);
            format!("slot {i}: col {} >= n_cols {}", e.cols[i], e.n_cols)
        },
    );
    r.check(
        e.data.iter().all(|v| v.is_finite()),
        subject,
        "val-finite",
        || {
            let i =
                e.data.iter().position(|v| !v.is_finite()).unwrap_or(0);
            format!("slot {i}: value {} not finite", e.data[i])
        },
    );
    r
}

/// DIA: lane layout size, strictly ascending in-range offsets,
/// out-of-band lane slots exactly zero, finite values.
pub fn check_dia(subject: &str, d: &Dia) -> CheckReport {
    let mut r = CheckReport::new();
    let n = d.n_rows;
    let sized = r.check(
        d.vals.len() == d.offsets.len() * n,
        subject,
        "layout-size",
        || {
            format!(
                "vals length {} != n_diags*n_rows = {}",
                d.vals.len(),
                d.offsets.len() * n
            )
        },
    );
    r.check(
        d.offsets.windows(2).all(|w| w[0] < w[1]),
        subject,
        "offsets-ascending",
        || {
            let i = d
                .offsets
                .windows(2)
                .position(|w| w[0] >= w[1])
                .unwrap_or(0);
            format!(
                "offsets[{}] = {} >= offsets[{}] = {}",
                i,
                d.offsets[i],
                i + 1,
                d.offsets[i + 1]
            )
        },
    );
    r.check(
        d.offsets.iter().all(|&o| {
            (o as i64) > -(n as i64) && (o as i64) < d.n_cols as i64
        }),
        subject,
        "offsets-range",
        || {
            let o = d
                .offsets
                .iter()
                .find(|&&o| {
                    (o as i64) <= -(n as i64) || (o as i64) >= d.n_cols as i64
                })
                .copied()
                .unwrap_or(0);
            format!("offset {o} never intersects a {n}x{} matrix", d.n_cols)
        },
    );
    if !sized {
        return r;
    }
    let band = (0..d.offsets.len()).find_map(|di| {
        let off = d.offsets[di] as i64;
        (0..n).find_map(|row| {
            let c = row as i64 + off;
            let out = c < 0 || c >= d.n_cols as i64;
            (out && d.vals[di * n + row] != 0.0).then_some((di, row))
        })
    });
    r.check(band.is_none(), subject, "out-of-band-zero", || {
        let (di, row) = band.unwrap_or((0, 0));
        format!(
            "diagonal {} row {row}: out-of-band slot holds {}",
            d.offsets[di],
            d.vals[di * n + row]
        )
    });
    r.check(
        d.vals.iter().all(|v| v.is_finite()),
        subject,
        "val-finite",
        || {
            let i =
                d.vals.iter().position(|v| !v.is_finite()).unwrap_or(0);
            format!("slot {i}: value {} not finite", d.vals[i])
        },
    );
    r
}

/// HYB: the ELL and COO halves individually, plus dimension agreement.
pub fn check_hyb(subject: &str, h: &Hyb) -> CheckReport {
    let mut r = check_ell(subject, &h.ell);
    r.merge(check_coo(subject, &h.coo));
    r.check(
        h.ell.n_rows == h.coo.n_rows && h.ell.n_cols == h.coo.n_cols,
        subject,
        "halves-dims",
        || {
            format!(
                "ell {}x{} vs coo {}x{}",
                h.ell.n_rows, h.ell.n_cols, h.coo.n_rows, h.coo.n_cols
            )
        },
    );
    r
}

/// CSR5: embedded row pointer, tile descriptor lengths, and the exact
/// descriptor semantics of `Csr5::from_csr` — `bit_flag[i]` iff `i`
/// starts a non-empty row, `tile_ptr[t]` names the row containing the
/// tile's first nonzero, `y_off` is the exclusive prefix of row
/// starts per tile, `seg_off[t]` iff the tile opens mid-row.
pub fn check_csr5(subject: &str, a: &Csr5) -> CheckReport {
    let mut r = CheckReport::new();
    let nnz = a.data.len();
    let aligned = r.check(
        a.indices.len() == nnz && a.bit_flag.len() == nnz,
        subject,
        "arrays-aligned",
        || {
            format!(
                "indices/bit_flag lengths {}/{} != data len {}",
                a.indices.len(),
                a.bit_flag.len(),
                nnz
            )
        },
    );
    let tile_ok = r.check(a.tile_nnz > 0, subject, "tile-nnz-positive", || {
        "tile_nnz = 0".to_string()
    });
    let ptr_ok = check_row_ptr(&mut r, subject, &a.ptr, a.n_rows, nnz);
    r.check(
        a.indices.iter().all(|&c| (c as usize) < a.n_cols),
        subject,
        "col-bounds",
        || {
            let i = a
                .indices
                .iter()
                .position(|&c| (c as usize) >= a.n_cols)
                .unwrap_or(0);
            format!(
                "nonzero {i}: col {} >= n_cols {}",
                a.indices[i], a.n_cols
            )
        },
    );
    r.check(
        a.data.iter().all(|v| v.is_finite()),
        subject,
        "val-finite",
        || {
            let i =
                a.data.iter().position(|v| !v.is_finite()).unwrap_or(0);
            format!("nonzero {i}: value {} not finite", a.data[i])
        },
    );
    if !(aligned && tile_ok && ptr_ok) {
        return r;
    }
    let n_tiles = nnz.div_ceil(a.tile_nnz).max(1);
    let desc = r.check(
        a.tile_ptr.len() == n_tiles
            && a.y_off.len() == n_tiles
            && a.seg_off.len() == n_tiles,
        subject,
        "descriptor-len",
        || {
            format!(
                "tile_ptr/y_off/seg_off lengths {}/{}/{} != n_tiles {}",
                a.tile_ptr.len(),
                a.y_off.len(),
                a.seg_off.len(),
                n_tiles
            )
        },
    );
    if !desc {
        return r;
    }
    // Recompute the descriptors from the (validated) row pointer and
    // compare — the stored arrays must agree with `from_csr`.
    let mut expect_flag = vec![false; nnz];
    for row in 0..a.n_rows {
        if a.ptr[row] < a.ptr[row + 1] {
            expect_flag[a.ptr[row]] = true;
        }
    }
    r.check(a.bit_flag == expect_flag, subject, "bit-flag", || {
        let i = a
            .bit_flag
            .iter()
            .zip(&expect_flag)
            .position(|(g, w)| g != w)
            .unwrap_or(0);
        format!(
            "bit_flag[{i}] = {} but nonzero {i} {} a row",
            a.bit_flag[i],
            if expect_flag[i] { "starts" } else { "does not start" }
        )
    });
    let mut tile_ptr_bad = None;
    let mut seg_off_bad = None;
    let mut y_off_bad = None;
    let mut starts_before = 0u32;
    for t in 0..n_tiles {
        let begin = t * a.tile_nnz;
        if begin < nnz {
            let row = a.tile_ptr[t] as usize;
            let contains = row < a.n_rows
                && a.ptr[row] <= begin
                && begin < a.ptr[row + 1];
            if !contains && tile_ptr_bad.is_none() {
                tile_ptr_bad = Some(t);
            }
            if a.seg_off[t] != !expect_flag[begin] && seg_off_bad.is_none() {
                seg_off_bad = Some(t);
            }
        } else {
            if a.tile_ptr[t] as usize != a.n_rows.saturating_sub(1)
                && tile_ptr_bad.is_none()
            {
                tile_ptr_bad = Some(t);
            }
            if a.seg_off[t] && seg_off_bad.is_none() {
                seg_off_bad = Some(t);
            }
        }
        if a.y_off[t] != starts_before && y_off_bad.is_none() {
            y_off_bad = Some(t);
        }
        let end = ((t + 1) * a.tile_nnz).min(nnz);
        starts_before += expect_flag[begin.min(nnz)..end]
            .iter()
            .filter(|&&b| b)
            .count() as u32;
    }
    r.check(tile_ptr_bad.is_none(), subject, "tile-ptr-row", || {
        let t = tile_ptr_bad.unwrap_or(0);
        format!(
            "tile {t}: tile_ptr {} does not contain nonzero {}",
            a.tile_ptr[t],
            t * a.tile_nnz
        )
    });
    r.check(seg_off_bad.is_none(), subject, "seg-off", || {
        let t = seg_off_bad.unwrap_or(0);
        format!("tile {t}: seg_off {} contradicts bit_flag", a.seg_off[t])
    });
    r.check(y_off_bad.is_none(), subject, "y-off-prefix", || {
        let t = y_off_bad.unwrap_or(0);
        format!("tile {t}: y_off {} is not the row-start prefix", a.y_off[t])
    });
    r
}

/// CSR5 against the CSR it claims to mirror: dimensions plus the
/// verbatim `ptr`/`indices`/`data` copies (values bitwise).
pub fn check_csr5_vs_csr(subject: &str, a: &Csr5, csr: &Csr) -> CheckReport {
    let mut r = check_csr5(subject, a);
    r.check(
        a.n_rows == csr.n_rows && a.n_cols == csr.n_cols,
        subject,
        "dims",
        || {
            format!(
                "csr5 {}x{} vs csr {}x{}",
                a.n_rows, a.n_cols, csr.n_rows, csr.n_cols
            )
        },
    );
    r.check(a.ptr == csr.ptr, subject, "ptr-verbatim", || {
        "csr5 row pointer differs from the source CSR".to_string()
    });
    r.check(a.indices == csr.indices, subject, "indices-verbatim", || {
        let i = a
            .indices
            .iter()
            .zip(&csr.indices)
            .position(|(x, y)| x != y)
            .unwrap_or(a.indices.len().min(csr.indices.len()));
        format!("first divergence from the source CSR at nonzero {i}")
    });
    r.check(
        a.data.len() == csr.data.len()
            && a.data
                .iter()
                .zip(&csr.data)
                .all(|(x, y)| x.to_bits() == y.to_bits()),
        subject,
        "data-verbatim",
        || {
            let i = a
                .data
                .iter()
                .zip(&csr.data)
                .position(|(x, y)| x.to_bits() != y.to_bits())
                .unwrap_or(a.data.len().min(csr.data.len()));
            format!("first value divergence from the source CSR at {i}")
        },
    );
    r
}

/// SELL-C-σ structure: C domain, σ normalized, chunk prefix
/// consistency, perm-is-a-permutation with σ-window locality,
/// in-bounds columns, finite values.
pub fn check_sell(subject: &str, s: &SellCSigma) -> CheckReport {
    let mut r = CheckReport::new();
    if !r.check(s.c >= 1 && s.c <= 64, subject, "c-domain", || {
        format!("chunk height C = {} outside 1..=64", s.c)
    }) {
        return r;
    }
    let sigma_ok = r.check(
        s.sigma == normalize_sigma(s.c, s.sigma, s.n_rows),
        subject,
        "sigma-normalized",
        || {
            format!(
                "sigma = {} != normalize_sigma = {}",
                s.sigma,
                normalize_sigma(s.c, s.sigma, s.n_rows)
            )
        },
    );
    let n_chunks = s.n_rows.div_ceil(s.c);
    let counts = r.check(
        s.chunk_len.len() == n_chunks && s.chunk_ptr.len() == n_chunks + 1,
        subject,
        "chunk-count",
        || {
            format!(
                "chunk_len/chunk_ptr lengths {}/{} for {} chunks",
                s.chunk_len.len(),
                s.chunk_ptr.len(),
                n_chunks
            )
        },
    );
    r.check(
        s.cols.len() == s.vals.len(),
        subject,
        "arrays-aligned",
        || format!("cols len {} != vals len {}", s.cols.len(), s.vals.len()),
    );
    if counts {
        let prefix_bad = (0..n_chunks).find(|&k| {
            s.chunk_ptr[k + 1].checked_sub(s.chunk_ptr[k])
                != Some(s.chunk_len[k] as usize * s.c)
        });
        let prefix_ok = r.check(
            s.chunk_ptr[0] == 0 && prefix_bad.is_none(),
            subject,
            "chunk-prefix",
            || match prefix_bad {
                Some(k) => format!(
                    "chunk {k}: ptr delta != chunk_len[{k}] * C = {}",
                    s.chunk_len[k] as usize * s.c
                ),
                None => format!("chunk_ptr[0] = {} != 0", s.chunk_ptr[0]),
            },
        );
        r.check(
            !prefix_ok || s.chunk_ptr[n_chunks] == s.cols.len(),
            subject,
            "chunk-total",
            || {
                format!(
                    "chunk_ptr[last] = {} != cols len {}",
                    s.chunk_ptr[n_chunks],
                    s.cols.len()
                )
            },
        );
    }
    let perm_len = r.check(
        s.perm.len() == s.n_rows,
        subject,
        "perm-len",
        || format!("perm len {} != n_rows {}", s.perm.len(), s.n_rows),
    );
    if perm_len {
        let mut seen = vec![false; s.n_rows];
        let mut perm_bad = None;
        for (slot, &row) in s.perm.iter().enumerate() {
            if (row as usize) >= s.n_rows || seen[row as usize] {
                perm_bad = Some(slot);
                break;
            }
            seen[row as usize] = true;
        }
        r.check(perm_bad.is_none(), subject, "perm-permutation", || {
            let slot = perm_bad.unwrap_or(0);
            format!(
                "slot {slot}: row {} out of bounds or repeated",
                s.perm[slot]
            )
        });
        if sigma_ok && perm_bad.is_none() {
            let window_bad = s
                .perm
                .iter()
                .enumerate()
                .find(|(slot, &row)| row as usize / s.sigma != slot / s.sigma);
            r.check(window_bad.is_none(), subject, "perm-window", || {
                let (slot, &row) = window_bad.unwrap_or((0, &0));
                format!("slot {slot}: row {row} left its sigma window")
            });
        }
    }
    r.check(
        s.cols.iter().all(|&c| (c as usize) < s.n_cols.max(1)),
        subject,
        "col-bounds",
        || {
            let i = s
                .cols
                .iter()
                .position(|&c| (c as usize) >= s.n_cols.max(1))
                .unwrap_or(0);
            format!("slot {i}: col {} >= n_cols {}", s.cols[i], s.n_cols)
        },
    );
    r.check(
        s.vals.iter().all(|v| v.is_finite()),
        subject,
        "val-finite",
        || {
            let i =
                s.vals.iter().position(|v| !v.is_finite()).unwrap_or(0);
            format!("slot {i}: value {} not finite", s.vals[i])
        },
    );
    r
}

/// SELL-C-σ against the CSR it claims to pack: chunk widths are the
/// per-chunk row maxima, packed content is bitwise the CSR rows, and
/// padding is an exact no-op (value 0.0 against the row's own last
/// column — 0 for empty rows and ghost lanes past the last row).
pub fn check_sell_vs_csr(
    subject: &str,
    s: &SellCSigma,
    csr: &Csr,
) -> CheckReport {
    let mut r = check_sell(subject, s);
    r.check(
        s.n_rows == csr.n_rows && s.n_cols == csr.n_cols,
        subject,
        "dims",
        || {
            format!(
                "sell {}x{} vs csr {}x{}",
                s.n_rows, s.n_cols, csr.n_rows, csr.n_cols
            )
        },
    );
    if !r.is_clean() {
        return r;
    }
    let base_csr = check_csr(subject, csr);
    if !base_csr.is_clean() {
        r.merge(base_csr);
        return r;
    }
    let n_chunks = s.n_chunks();
    let mut width_bad = None;
    let mut content_bad = None;
    let mut padding_bad = None;
    for k in 0..n_chunks {
        let width = s.chunk_len[k] as usize;
        let base = s.chunk_ptr[k];
        let rows = s.c.min(s.n_rows - k * s.c);
        let max_nnz = (0..rows)
            .map(|p| csr.row_nnz(s.perm[k * s.c + p] as usize))
            .max()
            .unwrap_or(0);
        if width != max_nnz && width_bad.is_none() {
            width_bad = Some((k, width, max_nnz));
        }
        for p in 0..s.c {
            let lanes = if p < rows {
                let row = s.perm[k * s.c + p] as usize;
                let (rc, rv) = csr.row(row);
                let take = rc.len().min(width);
                for j in 0..take {
                    let at = base + j * s.c + p;
                    if (s.cols[at] != rc[j]
                        || s.vals[at].to_bits() != rv[j].to_bits())
                        && content_bad.is_none()
                    {
                        content_bad = Some((k, row, j));
                    }
                }
                (take, rc.last().copied().unwrap_or(0))
            } else {
                // Ghost lane past the last row of a ragged tail
                // chunk: stays at the zero-initialized fill.
                (0, 0)
            };
            let (from, pad_col) = lanes;
            for j in from..width {
                let at = base + j * s.c + p;
                if (s.vals[at] != 0.0 || s.cols[at] != pad_col)
                    && padding_bad.is_none()
                {
                    padding_bad = Some((k, p, j));
                }
            }
        }
    }
    r.check(width_bad.is_none(), subject, "chunk-width", || {
        let (k, width, max_nnz) = width_bad.unwrap_or((0, 0, 0));
        format!("chunk {k}: width {width} != max row nnz {max_nnz}")
    });
    r.check(content_bad.is_none(), subject, "content-verbatim", || {
        let (k, row, j) = content_bad.unwrap_or((0, 0, 0));
        format!("chunk {k}: packed row {row} diverges from CSR at col {j}")
    });
    r.check(padding_bad.is_none(), subject, "padding-no-op", || {
        let (k, p, j) = padding_bad.unwrap_or((0, 0, 0));
        format!("chunk {k} lane {p} slot {j}: padding is not a no-op")
    });
    r
}

/// Partition: parameter domains plus exactly-once coverage of the
/// row/tile/chunk space (via `Partition::validate`, with the
/// divide-by-zero hazards it assumes away checked first).
pub fn check_partition(
    subject: &str,
    p: &Partition,
    csr: &Csr,
) -> CheckReport {
    let mut r = CheckReport::new();
    match p {
        Partition::Tiles { tile_nnz, .. } => {
            // `Partition::validate` divides by tile_nnz.
            if !r.check(*tile_nnz > 0, subject, "tile-nnz-positive", || {
                "tile_nnz = 0".to_string()
            }) {
                return r;
            }
        }
        Partition::SellChunks { c, .. } => {
            r.check(*c >= 1 && *c <= 64, subject, "c-domain", || {
                format!("chunk height C = {c} outside 1..=64")
            });
        }
        Partition::Rows { .. } => {}
    }
    match p.validate(csr) {
        Ok(()) => {
            r.checked += 1;
        }
        Err(e) => {
            r.check(false, subject, "coverage", || e);
        }
    }
    r
}

/// Full plan verification: schedule ↔ partition ↔ format parameter
/// agreement, the materialized format against the source CSR, slot
/// coverage for both the single-vector and the memoized SpMM
/// partitions, and the pre-rendered names — everything a cached plan
/// promises the executor.
pub fn check_plan(subject: &str, plan: &Plan, csr: &Csr) -> CheckReport {
    let mut r = CheckReport::new();
    r.check(plan.n_threads >= 1, subject, "threads-positive", || {
        "plan has zero threads".to_string()
    });
    r.check(
        plan.partition.n_threads() == plan.n_threads,
        subject,
        "slot-count",
        || {
            format!(
                "partition has {} slots for {} threads",
                plan.partition.n_threads(),
                plan.n_threads
            )
        },
    );
    r.check(
        plan.spmm_partition.len() == plan.n_threads,
        subject,
        "spmm-slot-count",
        || {
            format!(
                "spmm partition has {} slots for {} threads",
                plan.spmm_partition.len(),
                plan.n_threads
            )
        },
    );
    // Schedule ↔ partition family and parameters. The partition keeps
    // the schedule's σ verbatim (un-normalized) — `sched::partition`
    // passes it through and `sell_perm` re-normalizes internally.
    let family_ok = match (plan.schedule, &plan.partition) {
        (
            Schedule::CsrRowStatic
            | Schedule::CsrRowBalanced
            | Schedule::CsrDynamic { .. },
            Partition::Rows { .. },
        ) => true,
        (
            Schedule::Csr5Tiles { tile_nnz },
            Partition::Tiles { tile_nnz: pt, .. },
        ) => *pt == tile_nnz,
        (
            Schedule::SellChunks { c, sigma },
            Partition::SellChunks { c: pc, sigma: ps, .. },
        ) => *pc == c && *ps == sigma,
        _ => false,
    };
    r.check(family_ok, subject, "schedule-partition", || {
        format!(
            "partition family/parameters disagree with schedule {}",
            plan.schedule.name()
        )
    });
    // Schedule ↔ materialized format. The format stores the
    // *normalized* σ (what `SellCSigma::from_csr` rounds to).
    let format_ok = match (plan.schedule, &plan.format) {
        (
            Schedule::CsrRowStatic
            | Schedule::CsrRowBalanced
            | Schedule::CsrDynamic { .. },
            PlannedFormat::Csr,
        ) => true,
        (Schedule::Csr5Tiles { tile_nnz }, PlannedFormat::Csr5(a)) => {
            a.tile_nnz == tile_nnz
        }
        (Schedule::SellChunks { c, sigma }, PlannedFormat::Sell(s)) => {
            s.c == c && s.sigma == normalize_sigma(c, sigma, csr.n_rows)
        }
        _ => false,
    };
    r.check(format_ok, subject, "schedule-format", || {
        format!(
            "materialized format disagrees with schedule {}",
            plan.schedule.name()
        )
    });
    match &plan.format {
        PlannedFormat::Csr => {}
        PlannedFormat::Csr5(a) => r.merge(check_csr5_vs_csr(subject, a, csr)),
        PlannedFormat::Sell(s) => r.merge(check_sell_vs_csr(subject, s, csr)),
    }
    r.merge(check_partition(subject, &plan.partition, csr));
    r.check(
        plan.spmm_schedule == exec::effective_spmm_schedule(plan.schedule),
        subject,
        "spmm-schedule",
        || {
            format!(
                "spmm schedule {} is not the effective remap {}",
                plan.spmm_schedule.name(),
                exec::effective_spmm_schedule(plan.schedule).name()
            )
        },
    );
    check_rows_cover(
        &mut r,
        subject,
        "spmm-coverage",
        &plan.spmm_partition,
        csr.n_rows,
    );
    r.check(
        plan.schedule_name == plan.schedule.name(),
        subject,
        "schedule-name",
        || {
            format!(
                "pre-rendered name {:?} != {:?}",
                plan.schedule_name,
                plan.schedule.name()
            )
        },
    );
    r.check(
        plan.spmm_schedule_name == plan.spmm_schedule.name(),
        subject,
        "spmm-schedule-name",
        || {
            format!(
                "pre-rendered spmm name {:?} != {:?}",
                plan.spmm_schedule_name,
                plan.spmm_schedule.name()
            )
        },
    );
    r
}

/// Plan cache bookkeeping: entry versions start at 1 and only move by
/// `replace` (so the sum of per-entry bumps is bounded by the global
/// replacement counter), and a bounded cache never overfills.
pub fn check_plan_cache(subject: &str, cache: &PlanCache) -> CheckReport {
    let mut r = CheckReport::new();
    let versions = cache.versions();
    let zero = versions.iter().find(|&&(_, v)| v == 0);
    r.check(zero.is_none(), subject, "version-positive", || {
        let (fp, _) = zero.copied().unwrap_or((0, 0));
        format!("fingerprint {fp:x}: entry version 0 (must start at 1)")
    });
    let bumps: u64 = versions.iter().map(|&(_, v)| v.saturating_sub(1)).sum();
    r.check(
        bumps <= cache.replacements(),
        subject,
        "version-monotone",
        || {
            format!(
                "{} version bumps exceed {} recorded replacements",
                bumps,
                cache.replacements()
            )
        },
    );
    r.check(
        cache.capacity() == 0 || cache.len() <= cache.capacity(),
        subject,
        "capacity",
        || {
            format!(
                "{} entries in a cache capped at {}",
                cache.len(),
                cache.capacity()
            )
        },
    );
    r
}

/// Allocation-free plan sanity for the serve path (the
/// `PlanConfig::validate` seam): O(partition slots), no heap, no
/// per-nonzero scans. Checks the cross-structure agreements a cached
/// plan could violate — schedule/partition/format family and
/// parameters, slot counts, and coverage *totals* (contiguity for
/// tile/chunk ranges, row-count sum for row ranges; the deep
/// exactly-once bitmap lives in [`check_plan`]).
pub fn quick_plan_check(plan: &Plan, csr: &Csr) -> Result<(), &'static str> {
    if plan.n_threads == 0 {
        return Err("plan has zero threads");
    }
    match (plan.schedule, &plan.partition) {
        (
            Schedule::CsrRowStatic
            | Schedule::CsrRowBalanced
            | Schedule::CsrDynamic { .. },
            Partition::Rows { per_thread },
        ) => {
            if !matches!(plan.format, PlannedFormat::Csr) {
                return Err("row schedule with a converted format");
            }
            if per_thread.len() != plan.n_threads {
                return Err("partition slot count != n_threads");
            }
            let mut covered = 0usize;
            for ranges in per_thread {
                for &(r0, r1) in ranges {
                    if r0 > r1 || r1 > csr.n_rows {
                        return Err("row range out of bounds");
                    }
                    covered += r1 - r0;
                }
            }
            if covered != csr.n_rows {
                return Err("row partition does not cover the matrix");
            }
        }
        (
            Schedule::Csr5Tiles { tile_nnz },
            Partition::Tiles { tile_nnz: pt, per_thread },
        ) => {
            if *pt == 0 {
                return Err("tile partition with tile_nnz = 0");
            }
            if *pt != tile_nnz {
                return Err("tile size disagrees with schedule");
            }
            if per_thread.len() != plan.n_threads {
                return Err("partition slot count != n_threads");
            }
            let n_tiles = csr.nnz().div_ceil(*pt).max(1);
            let mut expect = 0usize;
            for &(t0, t1) in per_thread {
                if t0 != expect || t1 < t0 {
                    return Err("tile ranges not contiguous");
                }
                expect = t1;
            }
            if expect != n_tiles {
                return Err("tile partition does not cover the matrix");
            }
            match &plan.format {
                PlannedFormat::Csr5(a) => {
                    if a.tile_nnz != *pt
                        || a.n_rows != csr.n_rows
                        || a.n_cols != csr.n_cols
                        || a.data.len() != csr.data.len()
                    {
                        return Err("csr5 format disagrees with the matrix");
                    }
                }
                _ => return Err("csr5 schedule without a csr5 format"),
            }
        }
        (
            Schedule::SellChunks { c, sigma },
            Partition::SellChunks { c: pc, sigma: ps, per_thread },
        ) => {
            if *pc != c || *ps != sigma {
                return Err("sell partition parameters disagree");
            }
            if c == 0 || c > 64 {
                return Err("sell chunk height outside 1..=64");
            }
            if per_thread.len() != plan.n_threads {
                return Err("partition slot count != n_threads");
            }
            let n_chunks = csr.n_rows.div_ceil(c);
            let mut expect = 0usize;
            for &(k0, k1) in per_thread {
                if k0 != expect || k1 < k0 {
                    return Err("chunk ranges not contiguous");
                }
                expect = k1;
            }
            if expect != n_chunks {
                return Err("chunk partition does not cover the matrix");
            }
            match &plan.format {
                PlannedFormat::Sell(s) => {
                    if s.c != c
                        || s.sigma != normalize_sigma(c, sigma, csr.n_rows)
                        || s.n_rows != csr.n_rows
                        || s.n_cols != csr.n_cols
                        || s.perm.len() != csr.n_rows
                    {
                        return Err("sell format disagrees with the matrix");
                    }
                }
                _ => return Err("sell schedule without a sell format"),
            }
        }
        _ => return Err("schedule/partition family mismatch"),
    }
    if plan.spmm_schedule != exec::effective_spmm_schedule(plan.schedule) {
        return Err("spmm schedule is not the effective remap");
    }
    if plan.spmm_partition.len() != plan.n_threads {
        return Err("spmm partition slot count != n_threads");
    }
    let mut covered = 0usize;
    for ranges in &plan.spmm_partition {
        for &(r0, r1) in ranges {
            if r0 > r1 || r1 > csr.n_rows {
                return Err("spmm row range out of bounds");
            }
            covered += r1 - r0;
        }
    }
    if covered != csr.n_rows {
        return Err("spmm partition does not cover the matrix");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::plan::{build_plan, PlanConfig, Planner};
    use crate::sparse::Coo;
    use crate::util::rng::Pcg32;

    fn random_csr(rng: &mut Pcg32, n: usize, max_deg: usize) -> Csr {
        let mut coo = Coo::new(n, n);
        for r in 0..n {
            for c in rng.sample_distinct(n, rng.gen_range(max_deg + 1)) {
                coo.push(r, c, rng.gen_f64() - 0.5);
            }
        }
        coo.to_csr()
    }

    #[test]
    fn clean_structures_pass() {
        let mut rng = Pcg32::new(0xC0DE);
        let csr = random_csr(&mut rng, 200, 9);
        assert!(check_csr("m", &csr).is_clean());
        let a = Csr5::from_csr(&csr, 64);
        assert!(check_csr5_vs_csr("m", &a, &csr).is_clean());
        let s = SellCSigma::from_csr(&csr, 8, 32);
        assert!(check_sell_vs_csr("m", &s, &csr).is_clean());
        let e = Ell::from_csr(&csr, None).unwrap();
        assert!(check_ell("m", &e).is_clean());
        let h = Hyb::from_csr(&csr, 3);
        assert!(check_hyb("m", &h).is_clean());
        // Empty matrix edge: every checker is total on it.
        let z = Csr::zero(0, 0);
        assert!(check_csr("z", &z).is_clean());
        assert!(check_csr5_vs_csr("z", &Csr5::from_csr(&z, 4), &z).is_clean());
    }

    #[test]
    fn corrupt_csr_names_the_invariant() {
        let mut rng = Pcg32::new(1);
        let base = random_csr(&mut rng, 64, 6);
        let mut a = base.clone();
        a.ptr[10] = a.ptr[11] + 1;
        let r = check_csr("m", &a);
        assert!(r.findings.iter().any(|f| f.invariant == "ptr-monotone"), "{r}");
        let mut b = base.clone();
        b.indices[0] = 64;
        let r = check_csr("m", &b);
        assert!(r.findings.iter().any(|f| f.invariant == "col-bounds"), "{r}");
        let mut c = base.clone();
        c.data[3] = f64::NAN;
        let r = check_csr("m", &c);
        assert!(r.findings.iter().any(|f| f.invariant == "val-finite"), "{r}");
    }

    #[test]
    fn corrupt_csr5_descriptors_are_caught() {
        let mut rng = Pcg32::new(2);
        let csr = random_csr(&mut rng, 100, 8);
        let base = Csr5::from_csr(&csr, 32);
        let cases: [(fn(&mut Csr5), &str); 4] = [
            (|a| a.bit_flag[0] = !a.bit_flag[0], "bit-flag"),
            (|a| a.tile_ptr[1] = a.n_rows as u32 + 7, "tile-ptr-row"),
            (|a| a.y_off[1] = a.y_off[1].wrapping_add(3), "y-off-prefix"),
            (|a| a.seg_off[0] = !a.seg_off[0], "seg-off"),
        ];
        for (mutate, want) in cases {
            let mut a = base.clone();
            mutate(&mut a);
            let r = check_csr5("m", &a);
            assert!(
                r.findings.iter().any(|f| f.invariant == want),
                "expected {want}: {r}"
            );
        }
    }

    #[test]
    fn corrupt_sell_is_caught() {
        let mut rng = Pcg32::new(3);
        let csr = random_csr(&mut rng, 120, 7);
        let base = SellCSigma::from_csr(&csr, 8, 32);
        let mut a = base.clone();
        a.perm.swap(0, 40); // crosses a sigma window
        let r = check_sell("m", &a);
        assert!(r.findings.iter().any(|f| f.invariant == "perm-window"), "{r}");
        let mut b = base.clone();
        b.perm[0] = b.perm[1];
        let r = check_sell("m", &b);
        assert!(
            r.findings.iter().any(|f| f.invariant == "perm-permutation"),
            "{r}"
        );
        let mut c = base.clone();
        if let Some(v) = c.vals.iter_mut().find(|v| **v == 0.0) {
            *v = 1.5; // padding slot no longer a no-op
            let r = check_sell_vs_csr("m", &c, &csr);
            assert!(!r.is_clean());
        }
        let mut d = base;
        d.chunk_ptr[1] += 8;
        let r = check_sell("m", &d);
        assert!(r.findings.iter().any(|f| f.invariant == "chunk-prefix"), "{r}");
    }

    #[test]
    fn partition_and_plan_checks() {
        let mut rng = Pcg32::new(4);
        let csr = random_csr(&mut rng, 150, 6);
        let plan = build_plan(&Planner::Heuristic, &PlanConfig::default(), &csr);
        assert!(check_plan("m", &plan, &csr).is_clean());
        assert!(quick_plan_check(&plan, &csr).is_ok());

        // Overlapping slots.
        let p = Partition::Rows {
            per_thread: vec![vec![(0, 80)], vec![(70, 150)]],
        };
        let r = check_partition("m", &p, &csr);
        assert!(r.findings.iter().any(|f| f.invariant == "coverage"), "{r}");
        // Zero tile size must not panic the checker.
        let p = Partition::Tiles { tile_nnz: 0, per_thread: vec![(0, 1)] };
        let r = check_partition("m", &p, &csr);
        assert!(
            r.findings.iter().any(|f| f.invariant == "tile-nnz-positive"),
            "{r}"
        );

        // A plan whose memoized spmm partition lost a row.
        let mut bad = plan.clone();
        if let Some(last) = bad
            .spmm_partition
            .iter_mut()
            .rev()
            .find_map(|ranges| ranges.last_mut())
        {
            last.1 -= 1;
        }
        assert!(quick_plan_check(&bad, &csr).is_err());
        let r = check_plan("m", &bad, &csr);
        assert!(
            r.findings.iter().any(|f| f.invariant == "spmm-coverage"),
            "{r}"
        );
    }

    #[test]
    fn quick_check_matches_deep_check_on_family_mismatch() {
        let mut rng = Pcg32::new(5);
        let csr = random_csr(&mut rng, 90, 5);
        let plan = build_plan(&Planner::Heuristic, &PlanConfig::default(), &csr);
        let mut bad = plan.clone();
        bad.schedule = Schedule::Csr5Tiles { tile_nnz: 64 };
        assert!(quick_plan_check(&bad, &csr).is_err());
        assert!(!check_plan("m", &bad, &csr).is_clean());
    }

    #[test]
    fn report_display_is_stable() {
        let mut r = CheckReport::new();
        assert!(r.is_clean());
        r.check(false, "mat", "ptr-monotone", || "ptr[1] > ptr[2]".into());
        let text = format!("{r}");
        assert!(text.contains("mat: ptr-monotone: ptr[1] > ptr[2]"), "{text}");
    }
}
