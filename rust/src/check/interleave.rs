//! Deterministic interleaving harness for the lock-free serve core.
//!
//! A mini-loom: instead of hoping the scheduler explores interesting
//! thread orderings, we *impose* them. Each round picks a seeded
//! permutation of the slot indices and forces the pool to complete the
//! slots in exactly that order: every slot spins (with `yield_now`)
//! until a shared turn counter reaches its assigned rank, does its
//! work, then advances the counter. Because the pool is sized so that
//! every slot is concurrently resident (`n_workers = n_slots - 1`,
//! dispatcher included), any schedule is reachable and progress is
//! guaranteed; a bounded spin converts a would-be deadlock into a
//! counted `stall` finding instead of a hung CI job.
//!
//! Each round exercises, under the forced schedule:
//! - `ExecPool` slot handoff: every slot runs exactly once, panics and
//!   lost wakeups would surface as stalls or double-executions;
//! - the `obs::trace` span rings: the pool's own kernel spans plus one
//!   explicit span per slot land in per-lane atomic rings on the
//!   virtual clock, and [`TraceRecorder::validate`] checks the rings
//!   for torn records, bad stage/schedule tags, and non-monotone
//!   per-lane end times afterwards.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use super::{CheckReport, Finding};
use crate::exec::ExecPool;
use crate::obs::{ClockMode, Stage, TraceConfig, TraceRecorder};
use crate::util::ordatomic::OrdAtomicUsize;
use crate::util::rng::Pcg32;

/// Spin budget per slot before the harness declares a stall. Spins are
/// `yield_now` calls, so this is generous (seconds of wall time) while
/// still bounding a pathological schedule.
const MAX_SPINS: u64 = 20_000_000;

/// Marker stored into `order[t]` before any slot has claimed turn `t`.
const UNSET: usize = usize::MAX;

/// Configuration for one harness sweep.
#[derive(Clone, Copy, Debug)]
pub struct InterleaveConfig {
    /// Base seed; every (slot-count, round) pair forks its own stream.
    pub seed: u64,
    /// Permutation rounds per slot count.
    pub rounds: usize,
    /// Slot counts 2..=max_slots are exercised.
    pub max_slots: usize,
    /// Span-ring capacity per lane. Small values force ring wraps so
    /// `validate` is exercised on wrapped rings too.
    pub ring_capacity: usize,
}

impl InterleaveConfig {
    /// CI-friendly sweep: a few slot counts, a few permutations each,
    /// a ring small enough to wrap. Scaled further down under Miri,
    /// where every spin iteration is interpreted.
    pub fn quick(seed: u64) -> Self {
        InterleaveConfig {
            seed,
            rounds: if cfg!(miri) { 2 } else { 4 },
            max_slots: if cfg!(miri) { 3 } else { 4 },
            ring_capacity: 8,
        }
    }

    /// Heavier sweep for local runs and the `check` CLI default.
    pub fn full(seed: u64) -> Self {
        InterleaveConfig {
            seed,
            rounds: 16,
            max_slots: 6,
            ring_capacity: 32,
        }
    }

    fn sanitized(&self) -> InterleaveConfig {
        InterleaveConfig {
            seed: self.seed,
            rounds: self.rounds.max(1),
            max_slots: self.max_slots.clamp(2, 16),
            ring_capacity: self.ring_capacity.max(1),
        }
    }
}

/// Run the harness; every violated invariant becomes a finding in the
/// returned report.
pub fn run(cfg: &InterleaveConfig) -> CheckReport {
    let cfg = cfg.sanitized();
    let mut report = CheckReport::new();
    let mut rng = Pcg32::new(cfg.seed);
    for n_slots in 2..=cfg.max_slots {
        let mut slot_rng = rng.fork(n_slots as u64);
        run_slot_count(&cfg, n_slots, &mut slot_rng, &mut report);
    }
    report
}

fn run_slot_count(
    cfg: &InterleaveConfig,
    n_slots: usize,
    rng: &mut Pcg32,
    report: &mut CheckReport,
) {
    // One pool + one recorder per slot count: `set_trace` is
    // first-wins, and sizing workers to n_slots - 1 makes every slot
    // concurrently resident (workers + dispatcher == n_slots lanes).
    let pool = ExecPool::new(n_slots - 1);
    let trace_cfg = TraceConfig {
        enabled: true,
        sample: 1,
        ring_capacity: cfg.ring_capacity,
    };
    let rec = Arc::new(TraceRecorder::new(
        trace_cfg,
        ClockMode::Virtual,
        pool.n_workers() + 1,
    ));
    pool.set_trace(Arc::clone(&rec));

    let mut spans_before = rec.spans_recorded();
    for round in 0..cfg.rounds {
        let subject = format!("interleave(slots={n_slots},round={round})");

        // The forced schedule: rank[slot] is the turn at which the
        // slot may run; inv[turn] is the slot expected at that turn.
        let mut rank: Vec<usize> = (0..n_slots).collect();
        rng.shuffle(&mut rank);
        let mut inv = vec![0usize; n_slots];
        for (slot, &r) in rank.iter().enumerate() {
            inv[r] = slot;
        }

        // Keep the virtual clock far above any plausible wall-clock
        // span duration so `start = now - elapsed` stays positive, and
        // strictly increasing across rounds so per-lane end times stay
        // monotone no matter which lane executes which slot.
        let epoch_s = ((n_slots * cfg.rounds + round) as f64 + 1.0) * 3600.0;
        rec.set_virtual_s(epoch_s);
        let sched_code = round % 5 + 1;
        rec.set_kernel_ctx(sched_code);

        let turn = OrdAtomicUsize::named(0, "interleave.turn");
        let stalled = OrdAtomicUsize::named(0, "interleave.stalled");
        let executed: Vec<OrdAtomicUsize> = (0..n_slots)
            .map(|_| OrdAtomicUsize::named(0, "interleave.executed"))
            .collect();
        let order: Vec<OrdAtomicUsize> = (0..n_slots)
            .map(|_| OrdAtomicUsize::named(UNSET, "interleave.order"))
            .collect();

        {
            let rec = &rec;
            let rank = &rank;
            let turn = &turn;
            let stalled = &stalled;
            let executed = &executed;
            let order = &order;
            let work = move |slot: usize| {
                let my_turn = rank[slot];
                let mut spins: u64 = 0;
                // ord: Acquire spin — pairs with the Release store
                // below so each slot's writes are visible to the next.
                while turn.load(Ordering::Acquire) != my_turn {
                    std::thread::yield_now();
                    spins += 1;
                    if spins > MAX_SPINS {
                        // ord: Relaxed RMW — stall tally, read only
                        // after the pool joins all slots.
                        stalled.fetch_add(1, Ordering::Relaxed);
                        return;
                    }
                }
                // ord: Relaxed RMW — per-slot tally, read post-join.
                executed[slot].fetch_add(1, Ordering::Relaxed);
                // lint:allow(relaxed-store) ord: the turn protocol
                // makes this store race-free — only the slot holding
                // turn `my_turn` writes order[my_turn], and the driver
                // reads it after the pool's join.
                order[my_turn].store(slot, Ordering::Relaxed);
                // One explicit span per slot on the slot's own lane
                // (lanes == slots here), tagged with the round's
                // schedule code, zero duration at the virtual epoch.
                let now = rec.now_us();
                rec.record(slot, Stage::Reduce, sched_code, now, 0.0);
                // ord: Release store — publishes this slot's work to
                // whichever slot acquires the next turn.
                turn.store(my_turn + 1, Ordering::Release);
            };
            pool.run(n_slots, &work);
        }

        // ord: Relaxed loads below — the pool's join already ordered
        // every slot's writes before the driver reads the tallies.
        let stalls = stalled.load(Ordering::Relaxed);
        report.check(
            stalls == 0,
            &subject,
            "no-stall",
            || {
                format!(
                    "{stalls} slot(s) exhausted the spin budget waiting \
                     for their turn"
                )
            },
        );
        let mut exec_bad = None;
        for (slot, e) in executed.iter().enumerate() {
            // ord: Relaxed load — post-join tally read (see above).
            let n = e.load(Ordering::Relaxed);
            if n != 1 && exec_bad.is_none() {
                exec_bad = Some((slot, n));
            }
        }
        report.check(
            exec_bad.is_none() || stalls > 0,
            &subject,
            "executed-once",
            || {
                let (slot, n) = exec_bad.unwrap_or((0, 0));
                format!("slot {slot} executed {n} times (want 1)")
            },
        );
        if stalls == 0 {
            let mut order_bad = None;
            for (t, o) in order.iter().enumerate() {
                // ord: Relaxed load — post-join tally read (see above).
                let got = o.load(Ordering::Relaxed);
                if got != inv[t] && order_bad.is_none() {
                    order_bad = Some((t, got, inv[t]));
                }
            }
            report.check(
                order_bad.is_none(),
                &subject,
                "schedule-order",
                || {
                    let (t, got, want) = order_bad.unwrap_or((0, 0, 0));
                    format!(
                        "turn {t} ran slot {got}, schedule demanded \
                         slot {want}"
                    )
                },
            );
            // Every slot emits one explicit span and the pool one
            // kernel span per completed slot.
            let spans_now = rec.spans_recorded();
            let grew = spans_now.saturating_sub(spans_before);
            report.check(
                grew == 2 * n_slots,
                &subject,
                "span-accounting",
                || {
                    format!(
                        "recorded {grew} spans this round (want \
                         {} = 2 per slot)",
                        2 * n_slots
                    )
                },
            );
            spans_before = spans_now;
        } else {
            spans_before = rec.spans_recorded();
        }
    }

    // After all rounds: the rings (wrapped or not) must decode clean.
    let subject = format!("interleave(slots={n_slots})");
    for msg in rec.validate() {
        report.findings.push(Finding {
            subject: subject.clone(),
            invariant: "trace-well-formed",
            detail: msg,
        });
    }
    report.checked += 1;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_harness_runs_clean() {
        let report = run(&InterleaveConfig::quick(0xF7_2000));
        assert!(report.is_clean(), "harness found:\n{report}");
        assert!(report.checked > 0);
    }

    #[test]
    fn harness_is_deterministic_per_seed() {
        let a = run(&InterleaveConfig::quick(42));
        let b = run(&InterleaveConfig::quick(42));
        assert_eq!(a.is_clean(), b.is_clean());
        assert_eq!(a.checked, b.checked);
        assert_eq!(a.findings.len(), b.findings.len());
    }

    #[test]
    fn tiny_ring_forces_wraps_and_still_validates() {
        let cfg = InterleaveConfig {
            seed: 7,
            rounds: if cfg!(miri) { 3 } else { 6 },
            max_slots: 3,
            ring_capacity: 2,
        };
        let report = run(&cfg);
        // span-accounting stays exact even when the ring wraps (the
        // recorded counter is monotone, only the ring is bounded), and
        // wrapped rings must still decode clean.
        assert!(report.is_clean(), "harness found:\n{report}");
    }

    #[test]
    fn config_sanitizer_clamps_degenerate_values() {
        let cfg = InterleaveConfig {
            seed: 1,
            rounds: 0,
            max_slots: 0,
            ring_capacity: 0,
        };
        let report = run(&cfg);
        assert!(report.is_clean(), "harness found:\n{report}");
    }
}
