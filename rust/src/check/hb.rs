//! Vector-clock happens-before race detector for the lock-free serve
//! core.
//!
//! The PR 7 interleaving harness proved the pool/trace protocols
//! *functionally* correct under forced schedules; this module proves
//! the **synchronization itself** sound. The instrumented atomics
//! layer ([`crate::util::ordatomic`], `--features hbcheck`) captures
//! every atomic op as an [`Event`] in exact linearization order;
//! [`analyze`] replays the log with DJIT-style per-lane vector
//! clocks and reports, as counted findings:
//!
//! - **race candidates** — conflicting accesses to one cell that no
//!   happens-before edge orders, and
//! - **ordering-strength waste** (advisory) — acquire/release sites
//!   whose edges are never load-bearing on any explored schedule,
//!   i.e. hot-path downgrade candidates.
//!
//! ## The happens-before model
//!
//! Edges come from three sources:
//!
//! 1. **Program order** within a lane.
//! 2. **Release/acquire pairing**: a release-class write joins the
//!    writer's clock into a per-address release clock; an
//!    acquire-class read joins that accumulated clock into the
//!    reader. A *relaxed store* to the address breaks the release
//!    sequence (clears the clock); a relaxed RMW continues it —
//!    mirroring the C++11 release-sequence rules the analyzer
//!    approximates.
//! 3. **Fork/join pseudo-events** from `ExecPool::run`: the pool's
//!    Condvar-latch dispatch has `std::thread::scope` semantics
//!    (publish under mutex → workers claim → dispatcher blocks on
//!    the completion latch), so `run` logs a fork at dispatch and a
//!    join after the latch instead of the analyzer decoding mutex
//!    traffic. A fork joins the dispatcher's clock into every lane's
//!    next event; a join gathers all lanes into the dispatcher.
//!
//! ## The conflict model
//!
//! Two accesses to one address from different lanes conflict when at
//! least one writes — except pairs that are atomically arbitrated or
//! pure synchronization:
//!
//! - RMW vs RMW never conflicts (hardware arbitration — counters,
//!   ring cursors, slot claims are exactly this).
//! - Load vs RMW never conflicts (monitoring snapshots of counters).
//! - Two accesses that are both stronger than `Relaxed` never
//!   conflict (C++ atomics cannot data-race; the detector treats
//!   `Relaxed` accesses as morally-plain data whose ordering the
//!   surrounding protocol must supply, and sync-class accesses as
//!   the protocol itself). A relaxed store racing an *acquire* load
//!   still conflicts — that is the broken-release pattern.
//!
//! Cells constructed with `racy_ok` (documented last-writer-wins,
//! e.g. the trace kernel-context attribution) are exempt from
//! conflict reporting but still generate edges.

use std::collections::{BTreeMap, BTreeSet};

use super::{CheckReport, Finding};
use crate::util::ordatomic::{Event, MemOrd, OpKind};

/// Findings cap per analysis — a broken protocol should read as a
/// handful of lines, not a core dump.
const MAX_RACES: usize = 64;

/// Sync addresses probed for ordering waste per analysis.
const MAX_PROBES: usize = 32;

/// One side of a race candidate.
#[derive(Clone, Copy, Debug)]
pub struct Access {
    /// Capture lane id (process-level thread id).
    pub lane: usize,
    /// Event seq in the capture log.
    pub seq: usize,
    pub op: OpKind,
    pub ord: MemOrd,
}

impl std::fmt::Display for Access {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}({}) by lane {} (seq {})",
            self.op.label(),
            self.ord.label(),
            self.lane,
            self.seq
        )
    }
}

/// A conflicting pair of accesses no happens-before edge orders.
#[derive(Clone, Debug)]
pub struct RaceFinding {
    pub addr: usize,
    /// Audit label of the cell (from its constructor).
    pub site: &'static str,
    /// The earlier access (log order).
    pub first: Access,
    /// The later access.
    pub second: Access,
}

impl RaceFinding {
    /// Does either side perform the given op? (Test hook: fixtures
    /// assert the store-store / store-load classes are told apart.)
    pub fn involves(&self, op: OpKind) -> bool {
        self.first.op == op || self.second.op == op
    }
}

impl std::fmt::Display for RaceFinding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "`{}`: {} unordered with {}",
            self.site, self.first, self.second
        )
    }
}

/// Result of one [`analyze`] pass.
#[derive(Clone, Debug, Default)]
pub struct HbAnalysis {
    /// Race candidates (deduplicated per (cell, op-pair), capped).
    pub races: Vec<RaceFinding>,
    /// Race findings dropped by the cap.
    pub suppressed: usize,
    /// Advisory ordering-strength-waste notes (not counted findings:
    /// a wasted AcqRel is a perf bug, not a soundness bug).
    pub advice: Vec<String>,
    /// Events analyzed.
    pub events: usize,
    /// Release→acquire edges derived.
    pub edges: usize,
    /// Distinct lanes in the capture.
    pub lanes: usize,
}

/// A lane's vector clock (indices are dense lane slots).
#[derive(Clone, Debug, Default)]
struct Vc(Vec<u32>);

impl Vc {
    fn new(n: usize) -> Vc {
        Vc(vec![0; n])
    }

    fn get(&self, i: usize) -> u32 {
        self.0.get(i).copied().unwrap_or(0)
    }

    fn tick(&mut self, i: usize) {
        self.0[i] += 1;
    }

    fn join(&mut self, o: &Vc) {
        for (a, b) in self.0.iter_mut().zip(&o.0) {
            *a = (*a).max(*b);
        }
    }
}

/// Last access of one op class by one lane on one address. `plain`
/// additionally remembers the lane's last *relaxed* access when the
/// newest one is sync-class — HB of the newest access implies HB of
/// everything earlier in the lane, but the conflict *classification*
/// differs, so both must be checkable.
#[derive(Clone, Copy, Debug)]
struct Epoch {
    /// The owning lane's clock component at the access.
    c: u32,
    seq: usize,
    ord: MemOrd,
    op: OpKind,
}

#[derive(Clone, Copy, Debug)]
struct LanePair {
    last: Epoch,
    plain: Option<Epoch>,
}

#[derive(Debug, Default)]
struct AddrState {
    loads: BTreeMap<usize, LanePair>,
    stores: BTreeMap<usize, LanePair>,
    rmws: BTreeMap<usize, LanePair>,
}

fn record_epoch(map: &mut BTreeMap<usize, LanePair>, lane: usize, ep: Epoch) {
    let plain = (ep.ord == MemOrd::Relaxed).then_some(ep);
    map.entry(lane)
        .and_modify(|p| {
            p.last = ep;
            if plain.is_some() {
                p.plain = plain;
            }
        })
        .or_insert(LanePair { last: ep, plain });
}

/// The conflict model (see module docs).
fn conflicting(a_op: OpKind, a_ord: MemOrd, b_op: OpKind, b_ord: MemOrd) -> bool {
    use OpKind::{Load, Rmw, Store};
    let writes =
        matches!(a_op, Store | Rmw) || matches!(b_op, Store | Rmw);
    if !writes {
        return false;
    }
    if a_op == Rmw && b_op == Rmw {
        return false;
    }
    if (a_op == Load && b_op == Rmw) || (a_op == Rmw && b_op == Load) {
        return false;
    }
    if a_ord != MemOrd::Relaxed && b_ord != MemOrd::Relaxed {
        return false;
    }
    true
}

/// One full vector-clock pass. `disabled` downgrades every access to
/// that address to `Relaxed` in the model (both edge derivation and
/// conflict classification) — the "would this site survive a
/// downgrade?" probe behind the waste advice.
struct Once {
    races: Vec<RaceFinding>,
    race_keys: BTreeSet<(usize, OpKind, OpKind)>,
    suppressed: usize,
    edges: usize,
    edges_by_addr: BTreeMap<usize, usize>,
    lanes: usize,
}

fn analyze_once(events: &[Event], disabled: Option<usize>) -> Once {
    // Lane ids are process-global; remap to dense slots so clocks
    // stay O(lanes-in-capture).
    let mut lane_ids: Vec<usize> = events.iter().map(|e| e.lane).collect();
    lane_ids.sort_unstable();
    lane_ids.dedup();
    let n = lane_ids.len();
    let lane_ix =
        |lane: usize| lane_ids.binary_search(&lane).unwrap_or(0);

    let mut clocks: Vec<Vc> = (0..n).map(|_| Vc::new(n)).collect();
    let mut fork_vc: Option<Vc> = None;
    let mut fork_gen = 0u64;
    let mut fork_applied = vec![0u64; n];
    let mut rel: BTreeMap<usize, Vc> = BTreeMap::new();
    let mut states: BTreeMap<usize, AddrState> = BTreeMap::new();

    let mut races = Vec::new();
    let mut race_keys = BTreeSet::new();
    let mut suppressed = 0usize;
    let mut edges = 0usize;
    let mut edges_by_addr: BTreeMap<usize, usize> = BTreeMap::new();

    for e in events {
        let l = lane_ix(e.lane);
        // A pending fork reaches each lane at its next event.
        if let Some(fv) = &fork_vc {
            if fork_applied[l] != fork_gen {
                clocks[l].join(fv);
                fork_applied[l] = fork_gen;
            }
        }
        clocks[l].tick(l);
        match e.op {
            OpKind::Fork => {
                fork_gen += 1;
                fork_vc = Some(clocks[l].clone());
                fork_applied[l] = fork_gen;
            }
            OpKind::Join => {
                let mut merged = clocks[l].clone();
                for c in &clocks {
                    merged.join(c);
                }
                clocks[l] = merged;
            }
            OpKind::Load | OpKind::Store | OpKind::Rmw => {
                let ord = if disabled == Some(e.addr) {
                    MemOrd::Relaxed
                } else {
                    e.ord
                };
                // Acquire side: consume the accumulated release clock.
                if e.op != OpKind::Store && ord.acquires() {
                    if let Some(r) = rel.get(&e.addr) {
                        clocks[l].join(r);
                        edges += 1;
                        *edges_by_addr.entry(e.addr).or_insert(0) += 1;
                    }
                }
                let st = states.entry(e.addr).or_default();
                // Conflict scan against every other lane's last
                // accesses (racy_ok cells are exempt by contract).
                if e.racy_ok.is_none() {
                    let vc = &clocks[l];
                    for map in [&st.loads, &st.stores, &st.rmws] {
                        for (&m, pair) in map {
                            if m == l {
                                continue;
                            }
                            let old = if conflicting(
                                e.op,
                                ord,
                                pair.last.op,
                                pair.last.ord,
                            ) {
                                Some(pair.last)
                            } else {
                                pair.plain.filter(|p| {
                                    conflicting(e.op, ord, p.op, p.ord)
                                })
                            };
                            let Some(old) = old else { continue };
                            if vc.get(m) >= old.c {
                                continue;
                            }
                            let key = (e.addr, old.op, e.op);
                            if !race_keys.insert(key) {
                                continue;
                            }
                            if races.len() >= MAX_RACES {
                                suppressed += 1;
                                continue;
                            }
                            races.push(RaceFinding {
                                addr: e.addr,
                                site: e.site,
                                first: Access {
                                    lane: lane_ids[m],
                                    seq: old.seq,
                                    op: old.op,
                                    ord: old.ord,
                                },
                                second: Access {
                                    lane: e.lane,
                                    seq: e.seq,
                                    op: e.op,
                                    ord,
                                },
                            });
                        }
                    }
                }
                // Release side: publish, continue, or break the
                // release sequence.
                match e.op {
                    OpKind::Store => {
                        if ord.releases() {
                            let vc = clocks[l].clone();
                            rel.entry(e.addr)
                                .and_modify(|r| r.join(&vc))
                                .or_insert(vc);
                        } else {
                            rel.remove(&e.addr);
                        }
                    }
                    OpKind::Rmw => {
                        if ord.releases() {
                            let vc = clocks[l].clone();
                            rel.entry(e.addr)
                                .and_modify(|r| r.join(&vc))
                                .or_insert(vc);
                        }
                        // A relaxed RMW continues an existing release
                        // sequence: leave rel[addr] intact.
                    }
                    _ => {}
                }
                if e.racy_ok.is_none() {
                    let ep = Epoch {
                        c: clocks[l].get(l),
                        seq: e.seq,
                        ord,
                        op: e.op,
                    };
                    let st = states.entry(e.addr).or_default();
                    let map = match e.op {
                        OpKind::Load => &mut st.loads,
                        OpKind::Store => &mut st.stores,
                        _ => &mut st.rmws,
                    };
                    record_epoch(map, l, ep);
                }
            }
        }
    }

    Once {
        races,
        race_keys,
        suppressed,
        edges,
        edges_by_addr,
        lanes: n,
    }
}

/// Analyze a captured event log: derive happens-before, report race
/// candidates, and probe every sync-class site for ordering waste.
pub fn analyze(events: &[Event]) -> HbAnalysis {
    let base = analyze_once(events, None);
    let mut advice = Vec::new();

    // Downgrade probes: for each address with sync-class traffic,
    // re-run the analysis with that address modeled Relaxed. An
    // unchanged race set means its edges were never load-bearing on
    // these schedules — advisory, because coverage is only as wide as
    // the schedules explored.
    let mut sync_sites: BTreeMap<usize, &'static str> = BTreeMap::new();
    for e in events {
        if matches!(e.op, OpKind::Load | OpKind::Store | OpKind::Rmw)
            && e.ord != MemOrd::Relaxed
            && e.racy_ok.is_none()
        {
            sync_sites.entry(e.addr).or_insert(e.site);
        }
    }
    for (i, (&addr, &site)) in sync_sites.iter().enumerate() {
        if i >= MAX_PROBES {
            advice.push(format!(
                "... {} more sync site(s) not probed (cap {MAX_PROBES})",
                sync_sites.len() - MAX_PROBES
            ));
            break;
        }
        let paired = base.edges_by_addr.get(&addr).copied().unwrap_or(0);
        if paired == 0 {
            advice.push(format!(
                "`{site}`: acquire/release ordering never paired on any \
                 explored schedule (no acquire observed a release) — \
                 downgrade candidate (advisory)"
            ));
            continue;
        }
        let probe = analyze_once(events, Some(addr));
        if probe.race_keys == base.race_keys {
            advice.push(format!(
                "`{site}`: {paired} sync edge(s) derived but never \
                 load-bearing (downgrading to Relaxed adds no race on \
                 any explored schedule) — downgrade candidate (advisory)"
            ));
        }
    }

    HbAnalysis {
        races: base.races,
        suppressed: base.suppressed,
        advice,
        events: events.len(),
        edges: base.edges,
        lanes: base.lanes,
    }
}

/// Configuration for one [`run`] sweep (mirrors
/// [`super::interleave::InterleaveConfig`]).
#[derive(Clone, Copy, Debug)]
pub struct HbConfig {
    /// Base seed; every (slot-count, round) pair forks its own stream.
    pub seed: u64,
    /// Captured permutation rounds per slot count.
    pub rounds: usize,
    /// Slot counts 2..=max_slots are exercised.
    pub max_slots: usize,
    /// Span-ring capacity per lane (small values force ring wraps).
    pub ring_capacity: usize,
}

impl HbConfig {
    /// CI smoke: a few slot counts, a few schedules each.
    pub fn quick(seed: u64) -> Self {
        HbConfig { seed, rounds: 8, max_slots: 4, ring_capacity: 8 }
    }

    /// The acceptance sweep: 5 slot counts x 210 schedules = 1050
    /// seeded interleavings over the real core.
    pub fn full(seed: u64) -> Self {
        HbConfig { seed, rounds: 210, max_slots: 6, ring_capacity: 32 }
    }

    fn sanitized(&self) -> HbConfig {
        HbConfig {
            seed: self.seed,
            rounds: self.rounds.max(1),
            max_slots: self.max_slots.clamp(2, 16),
            // >= 2 keeps same-round ring claims on distinct slots, so
            // slot-field stores stay single-writer per fork window.
            ring_capacity: self.ring_capacity.max(2),
        }
    }
}

/// Outcome of a [`run`] sweep over the real serve core.
#[derive(Debug)]
pub struct HbRunReport {
    /// Race candidates and protocol violations as counted findings.
    pub report: CheckReport,
    /// Ordering-waste advisories (prefixed with their scenario).
    pub advice: Vec<String>,
    /// Seeded schedules explored.
    pub schedules: usize,
    /// Events captured across all scenarios.
    pub events: usize,
    /// Release→acquire edges derived.
    pub edges: usize,
}

/// Drive the instrumented serve core (ExecPool + TraceRecorder +
/// MetricsRegistry + sharded admission) through seeded permuted
/// schedules and analyze every capture. Only available under
/// `--features hbcheck` (the CLI surfaces a rebuild hint otherwise).
#[cfg(feature = "hbcheck")]
pub fn run(cfg: &HbConfig) -> HbRunReport {
    use crate::util::rng::Pcg32;

    let cfg = cfg.sanitized();
    let mut report = CheckReport::new();
    let mut advice = Vec::new();
    let mut schedules = 0usize;
    let mut events = 0usize;
    let mut edges = 0usize;

    let mut rng = Pcg32::new(cfg.seed);
    for n_slots in 2..=cfg.max_slots {
        let mut slot_rng = rng.fork(n_slots as u64);
        let analysis =
            pool_scenario(&cfg, n_slots, &mut slot_rng, &mut report);
        absorb(
            &format!("hb(slots={n_slots})"),
            &analysis,
            &mut report,
            &mut advice,
        );
        schedules += cfg.rounds;
        events += analysis.events;
        edges += analysis.edges;
    }

    let adm_rounds = cfg.rounds.min(16);
    let analysis = admission_scenario(adm_rounds, &mut report);
    absorb("hb(admission)", &analysis, &mut report, &mut advice);
    schedules += adm_rounds;
    events += analysis.events;
    edges += analysis.edges;

    HbRunReport { report, advice, schedules, events, edges }
}

/// Fold one capture's analysis into the sweep report: races become
/// counted findings, advice is namespaced, and race-freedom itself is
/// one counted invariant.
#[cfg(feature = "hbcheck")]
fn absorb(
    subject: &str,
    analysis: &HbAnalysis,
    report: &mut CheckReport,
    advice: &mut Vec<String>,
) {
    report.checked += 1;
    for race in &analysis.races {
        report.findings.push(Finding {
            subject: subject.to_string(),
            invariant: "hb-race",
            detail: race.to_string(),
        });
    }
    if analysis.suppressed > 0 {
        report.findings.push(Finding {
            subject: subject.to_string(),
            invariant: "hb-race",
            detail: format!(
                "... {} more race candidate(s) suppressed",
                analysis.suppressed
            ),
        });
    }
    for a in &analysis.advice {
        advice.push(format!("{subject}: {a}"));
    }
}

/// The interleave harness pattern, instrumented: forced permutation
/// schedules over a real `ExecPool` with tracing and metrics handles
/// hot, one capture per slot count, post-round protocol checks under
/// the same capture (driver-lane loads are join-ordered, so they must
/// not race either).
#[cfg(feature = "hbcheck")]
fn pool_scenario(
    cfg: &HbConfig,
    n_slots: usize,
    rng: &mut crate::util::rng::Pcg32,
    report: &mut CheckReport,
) -> HbAnalysis {
    use crate::exec::ExecPool;
    use crate::obs::{ClockMode, MetricsRegistry, Stage, TraceConfig, TraceRecorder};
    use crate::util::ordatomic::{capture, OrdAtomicUsize};
    use std::sync::atomic::Ordering;
    use std::sync::Arc;

    /// Spin budget per slot (tighter than interleave's: every probed
    /// spin takes the capture lock, so stalls must fail fast).
    const MAX_SPINS: u64 = 2_000_000;
    const UNSET: usize = usize::MAX;

    let pool = ExecPool::new(n_slots - 1);
    let trace_cfg = TraceConfig {
        enabled: true,
        sample: 1,
        ring_capacity: cfg.ring_capacity,
    };
    let rec = Arc::new(TraceRecorder::new(
        trace_cfg,
        ClockMode::Virtual,
        pool.n_workers() + 1,
    ));
    pool.set_trace(Arc::clone(&rec));
    let metrics = MetricsRegistry::new();
    let counter = metrics.counter("hb.slots");
    let gauge = metrics.gauge("hb.last_slot");
    let hist = metrics.histogram("hb.slot_ms");

    let mut findings: Vec<(String, &'static str, String)> = Vec::new();
    let ((), events) = capture::capture(|| {
        for round in 0..cfg.rounds {
            let subject =
                format!("hb(slots={n_slots},round={round})");
            let mut rank: Vec<usize> = (0..n_slots).collect();
            rng.shuffle(&mut rank);

            let epoch_s =
                ((n_slots * cfg.rounds + round) as f64 + 1.0) * 3600.0;
            rec.set_virtual_s(epoch_s);
            let sched_code = round % 5 + 1;
            rec.set_kernel_ctx(sched_code);

            let turn = OrdAtomicUsize::named(0, "hb.turn");
            let stalled = OrdAtomicUsize::named(0, "hb.stalled");
            let executed: Vec<OrdAtomicUsize> = (0..n_slots)
                .map(|_| OrdAtomicUsize::named(0, "hb.executed"))
                .collect();
            let order: Vec<OrdAtomicUsize> = (0..n_slots)
                .map(|_| OrdAtomicUsize::named(UNSET, "hb.order"))
                .collect();

            {
                let rec = &rec;
                let rank = &rank;
                let turn = &turn;
                let stalled = &stalled;
                let executed = &executed;
                let order = &order;
                let counter = &counter;
                let gauge = &gauge;
                let hist = &hist;
                let work = move |slot: usize| {
                    let my_turn = rank[slot];
                    let mut spins: u64 = 0;
                    // ord: Acquire pairs with the Release store that
                    // advances the turn — the edge that orders the
                    // previous slot's order[] write before ours (the
                    // waste probe proves it load-bearing).
                    while turn.load(Ordering::Acquire) != my_turn {
                        std::thread::yield_now();
                        spins += 1;
                        if spins > MAX_SPINS {
                            // ord: RMW arbitration; driver reads
                            // after the pool's join latch.
                            stalled.fetch_add(1, Ordering::Relaxed);
                            return;
                        }
                    }
                    // ord: RMW on a per-slot cell; the join latch
                    // orders the driver's post-run read.
                    executed[slot].fetch_add(1, Ordering::Relaxed);
                    counter.inc();
                    hist.observe(0.25);
                    gauge.set(slot as f64);
                    let now = rec.now_us();
                    rec.record(slot, Stage::Reduce, sched_code, now, 0.0);
                    // lint:allow(relaxed-store) ord: single writer —
                    // only the slot holding turn `my_turn` writes
                    // order[my_turn], and the turn handoff plus the
                    // join latch publish it to the next slot and the
                    // driver (hb-verified).
                    order[my_turn].store(slot, Ordering::Relaxed);
                    // ord: Release publishes this slot's work to the
                    // next turn-holder's Acquire spin.
                    turn.store(my_turn + 1, Ordering::Release);
                };
                pool.run(n_slots, &work);
            }

            // ord: driver-lane read after the join latch.
            let stalls = stalled.load(Ordering::Relaxed);
            if stalls > 0 {
                findings.push((
                    subject.clone(),
                    "no-stall",
                    format!(
                        "{stalls} slot(s) exhausted the spin budget"
                    ),
                ));
                continue;
            }
            for (slot, e) in executed.iter().enumerate() {
                // ord: driver-lane read after the join latch.
                let nx = e.load(Ordering::Relaxed);
                if nx != 1 {
                    findings.push((
                        subject.clone(),
                        "executed-once",
                        format!("slot {slot} executed {nx} times"),
                    ));
                }
            }
            for (t, o) in order.iter().enumerate() {
                // ord: driver-lane read after the join latch.
                let got = o.load(Ordering::Relaxed);
                if rank.get(got).copied() != Some(t) {
                    findings.push((
                        subject.clone(),
                        "schedule-order",
                        format!("turn {t} ran slot {got}"),
                    ));
                }
            }
        }
    });

    for (subject, invariant, detail) in findings {
        report.findings.push(Finding { subject, invariant, detail });
    }
    report.checked += 3; // no-stall / executed-once / schedule-order
    let subject = format!("hb(slots={n_slots})");
    for msg in rec.validate() {
        report.findings.push(Finding {
            subject: subject.clone(),
            invariant: "trace-well-formed",
            detail: msg,
        });
    }
    report.checked += 1;

    analyze(&events)
}

/// Sharded admission under capture: replicated matrices take the
/// round-robin path (`rr` RMW from the submitting lane), bounded
/// queues reject, and scoped drain workers bump the served counter —
/// the real `submit`/`serve` code, not a model of it.
#[cfg(feature = "hbcheck")]
fn admission_scenario(
    rounds: usize,
    report: &mut CheckReport,
) -> HbAnalysis {
    use crate::service::{
        MatrixRegistry, PlacementPolicy, PlanConfig, Planner, Request,
        ShardConfig, ShardedServer,
    };
    use crate::sparse::Csr;
    use crate::util::ordatomic::capture;
    use std::sync::Arc;

    let n = 16usize;
    let mut reg = MatrixRegistry::new();
    for i in 0..3 {
        reg.register(&format!("hb-identity-{i}"), Csr::identity(n));
    }
    let registry = Arc::new(reg);
    let cfg = ShardConfig {
        shards: 2,
        queue_cap: 4,
        workers_per_shard: 2,
        max_batch: 4,
        deadline_ms: 0.0,
        // Both replicated ("hot") matrices route via the rr counter.
        policy: PlacementPolicy::HotReplicate { hot: 2 },
        pooled: false,
        tune: None,
        trace: None,
    };
    let server = ShardedServer::new(
        registry,
        Planner::Heuristic,
        PlanConfig::default(),
        cfg,
    );

    let ((submitted, rejected, served), events) = capture::capture(|| {
        let mut submitted = 0usize;
        let mut rejected = 0usize;
        for round in 0..rounds {
            for k in 0..8 {
                let id = (round + k) % 3;
                let req = Request::new(id, vec![1.0f64; n]);
                submitted += 1;
                if server.submit(req).is_rejected() {
                    rejected += 1;
                }
            }
        }
        server.close();
        let served = server.serve();
        (submitted, rejected, served)
    });

    let subject = "hb(admission)";
    report.check(
        served + rejected == submitted,
        subject,
        "admission-accounting",
        || {
            format!(
                "{submitted} submitted != {served} served + \
                 {rejected} rejected"
            )
        },
    );
    report.check(
        rejected > 0,
        subject,
        "admission-pressure",
        || {
            "bounded queues never rejected — the rr/reject path went \
             unexercised"
                .to_string()
        },
    );

    analyze(&events)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(
        seq: usize,
        lane: usize,
        op: OpKind,
        addr: usize,
        ord: MemOrd,
    ) -> Event {
        Event { seq, lane, op, addr, ord, site: "syn", racy_ok: None }
    }

    #[test]
    fn unordered_store_store_is_a_race() {
        let events = [
            ev(0, 0, OpKind::Store, 100, MemOrd::Relaxed),
            ev(1, 1, OpKind::Store, 100, MemOrd::Relaxed),
        ];
        let a = analyze(&events);
        assert_eq!(a.races.len(), 1, "{:?}", a.races);
        assert!(a.races[0].involves(OpKind::Store));
        assert_eq!(a.races[0].addr, 100);
        assert_eq!(a.edges, 0);
    }

    #[test]
    fn unordered_store_load_is_a_race() {
        let events = [
            ev(0, 0, OpKind::Store, 100, MemOrd::Relaxed),
            ev(1, 1, OpKind::Load, 100, MemOrd::Relaxed),
        ];
        let a = analyze(&events);
        assert_eq!(a.races.len(), 1, "{:?}", a.races);
        assert!(a.races[0].involves(OpKind::Load));
        assert!(a.races[0].involves(OpKind::Store));
    }

    #[test]
    fn release_acquire_chain_orders_the_data() {
        // lane 0: data (plain) then flag (release);
        // lane 1: flag (acquire) then data (plain). Clean.
        let events = [
            ev(0, 0, OpKind::Store, 1, MemOrd::Relaxed),
            ev(1, 0, OpKind::Store, 2, MemOrd::Release),
            ev(2, 1, OpKind::Load, 2, MemOrd::Acquire),
            ev(3, 1, OpKind::Load, 1, MemOrd::Relaxed),
        ];
        let a = analyze(&events);
        assert!(a.races.is_empty(), "{:?}", a.races);
        assert_eq!(a.edges, 1);
        // The flag's sync is load-bearing: no downgrade advice.
        assert!(a.advice.is_empty(), "{:?}", a.advice);
    }

    #[test]
    fn broken_release_is_flagged_on_flag_and_data() {
        // Same shape, but the flag store is Relaxed: no edge, so the
        // data pair races AND the relaxed-store-vs-acquire-load pair
        // on the flag itself is the broken-release signature.
        let events = [
            ev(0, 0, OpKind::Store, 1, MemOrd::Relaxed),
            ev(1, 0, OpKind::Store, 2, MemOrd::Relaxed),
            ev(2, 1, OpKind::Load, 2, MemOrd::Acquire),
            ev(3, 1, OpKind::Load, 1, MemOrd::Relaxed),
        ];
        let a = analyze(&events);
        assert_eq!(a.edges, 0);
        assert!(
            a.races.iter().any(|r| r.addr == 1),
            "data race missing: {:?}",
            a.races
        );
        assert!(
            a.races.iter().any(|r| r.addr == 2),
            "broken-release on the flag missing: {:?}",
            a.races
        );
    }

    #[test]
    fn relaxed_store_breaks_the_release_sequence() {
        // Release publish, then a relaxed store to the same flag: the
        // acquire that follows reads the *relaxed* store's sequence,
        // which carries no edge — the data pair must race.
        let events = [
            ev(0, 0, OpKind::Store, 1, MemOrd::Relaxed),
            ev(1, 0, OpKind::Store, 2, MemOrd::Release),
            ev(2, 0, OpKind::Store, 2, MemOrd::Relaxed),
            ev(3, 1, OpKind::Load, 2, MemOrd::Acquire),
            ev(4, 1, OpKind::Load, 1, MemOrd::Relaxed),
        ];
        let a = analyze(&events);
        assert!(
            a.races.iter().any(|r| r.addr == 1),
            "cleared release sequence must unorder the data: {:?}",
            a.races
        );
    }

    #[test]
    fn relaxed_rmw_continues_the_release_sequence() {
        let events = [
            ev(0, 0, OpKind::Store, 1, MemOrd::Relaxed),
            ev(1, 0, OpKind::Store, 2, MemOrd::Release),
            ev(2, 0, OpKind::Rmw, 2, MemOrd::Relaxed),
            ev(3, 1, OpKind::Load, 2, MemOrd::Acquire),
            ev(4, 1, OpKind::Load, 1, MemOrd::Relaxed),
        ];
        let a = analyze(&events);
        assert!(a.races.is_empty(), "{:?}", a.races);
        assert_eq!(a.edges, 1);
    }

    #[test]
    fn fork_and_join_order_pool_style_handoff() {
        // Driver writes, forks; worker reads (ordered), writes back;
        // driver joins, reads back (ordered). Clean end to end.
        let events = [
            ev(0, 0, OpKind::Store, 1, MemOrd::Relaxed),
            ev(1, 0, OpKind::Fork, 0, MemOrd::SeqCst),
            ev(2, 1, OpKind::Load, 1, MemOrd::Relaxed),
            ev(3, 1, OpKind::Store, 2, MemOrd::Relaxed),
            ev(4, 0, OpKind::Join, 0, MemOrd::SeqCst),
            ev(5, 0, OpKind::Load, 2, MemOrd::Relaxed),
        ];
        let a = analyze(&events);
        assert!(a.races.is_empty(), "{:?}", a.races);

        // Control: the same accesses without fork/join race twice.
        let events = [
            ev(0, 0, OpKind::Store, 1, MemOrd::Relaxed),
            ev(1, 1, OpKind::Load, 1, MemOrd::Relaxed),
            ev(2, 1, OpKind::Store, 2, MemOrd::Relaxed),
            ev(3, 0, OpKind::Load, 2, MemOrd::Relaxed),
        ];
        let a = analyze(&events);
        assert_eq!(a.races.len(), 2, "{:?}", a.races);
    }

    #[test]
    fn rmw_arbitration_and_snapshots_never_race() {
        // Two lanes bump a counter, a third snapshots it — the
        // counter/cursor/tally idiom everywhere in the serve core.
        let events = [
            ev(0, 0, OpKind::Rmw, 7, MemOrd::Relaxed),
            ev(1, 1, OpKind::Rmw, 7, MemOrd::Relaxed),
            ev(2, 2, OpKind::Load, 7, MemOrd::Relaxed),
            ev(3, 0, OpKind::Rmw, 7, MemOrd::Relaxed),
        ];
        let a = analyze(&events);
        assert!(a.races.is_empty(), "{:?}", a.races);
    }

    #[test]
    fn racy_ok_cells_are_exempt_but_sync_cells_are_not() {
        let mut racy = ev(0, 0, OpKind::Store, 9, MemOrd::Relaxed);
        racy.racy_ok = Some("last-writer-wins by design");
        let mut racy2 = ev(1, 1, OpKind::Store, 9, MemOrd::Relaxed);
        racy2.racy_ok = Some("last-writer-wins by design");
        let a = analyze(&[racy, racy2]);
        assert!(a.races.is_empty(), "{:?}", a.races);
    }

    #[test]
    fn unpaired_release_draws_downgrade_advice() {
        let events = [ev(0, 0, OpKind::Store, 3, MemOrd::Release)];
        let a = analyze(&events);
        assert!(a.races.is_empty());
        assert_eq!(a.advice.len(), 1, "{:?}", a.advice);
        assert!(a.advice[0].contains("never paired"), "{:?}", a.advice);
    }

    #[test]
    fn non_load_bearing_sync_draws_downgrade_advice() {
        // A same-lane release/acquire pair derives an edge that can
        // never order anything cross-lane: downgrade candidate.
        let events = [
            ev(0, 0, OpKind::Store, 3, MemOrd::Release),
            ev(1, 0, OpKind::Load, 3, MemOrd::Acquire),
        ];
        let a = analyze(&events);
        assert!(a.races.is_empty());
        assert_eq!(a.advice.len(), 1, "{:?}", a.advice);
        assert!(
            a.advice[0].contains("never load-bearing"),
            "{:?}",
            a.advice
        );
    }

    #[test]
    fn race_findings_dedup_per_cell_and_op_pair() {
        // 40 unordered store pairs on one cell collapse to one
        // finding, not 40.
        let mut events = Vec::new();
        for i in 0..40 {
            events.push(ev(
                2 * i,
                i % 2,
                OpKind::Store,
                500,
                MemOrd::Relaxed,
            ));
            events.push(ev(
                2 * i + 1,
                (i + 1) % 2,
                OpKind::Store,
                500,
                MemOrd::Relaxed,
            ));
        }
        let a = analyze(&events);
        assert_eq!(a.races.len(), 1, "{:?}", a.races);
    }

    #[test]
    fn analysis_is_deterministic() {
        let events = [
            ev(0, 3, OpKind::Store, 1, MemOrd::Relaxed),
            ev(1, 9, OpKind::Load, 1, MemOrd::Relaxed),
            ev(2, 3, OpKind::Store, 2, MemOrd::Release),
            ev(3, 9, OpKind::Load, 2, MemOrd::Acquire),
        ];
        let a = analyze(&events);
        let b = analyze(&events);
        assert_eq!(a.races.len(), b.races.len());
        assert_eq!(a.edges, b.edges);
        assert_eq!(a.advice, b.advice);
        assert_eq!(a.lanes, 2, "dense lane remap");
    }
}
