//! `ft2000-spmv` — CLI front-end of the scalability-characterization
//! harness. See `cli::usage()` or run with no arguments.

use anyhow::Result;

use ft2000_spmv::autotune::{
    autotune_table, AutotuneConfig, Autotuner, Policy,
};
use ft2000_spmv::cli::{
    self, Cli, Command, MatrixSource, PlannerKind, TrafficPattern,
    TunePolicyKind,
};
use ft2000_spmv::coordinator::{
    build_dataset, profile_matrix, report, Campaign, ProfileConfig,
};
use ft2000_spmv::corpus::suite::SuiteSpec;
use ft2000_spmv::exec;
use ft2000_spmv::mlmodel::{Forest, ForestParams};
use ft2000_spmv::obs::{ClockMode, TraceConfig, TraceRecorder};
use ft2000_spmv::runtime::Runtime;
use ft2000_spmv::sched::Schedule;
use ft2000_spmv::service::{
    self, serve_queue, Arrivals, MatrixRegistry, PlacementPolicy,
    PlanConfig, Planner, Popularity, ReplayConfig, Request, RequestQueue,
    ServeEngine, ShardConfig, ShardedServer, WorkloadSpec,
};
use ft2000_spmv::sim::topology::{Placement, Topology};
use ft2000_spmv::sparse::{mm, Csr};
use ft2000_spmv::util::bench::{bench, black_box, BenchConfig};
use ft2000_spmv::util::table::Table;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = match cli::parse(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(cli) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(cli: Cli) -> Result<()> {
    match cli.command {
        Command::Sweep { suite, schedule, placement, threads, csv } => {
            sweep(suite, schedule, placement, threads, csv)
        }
        Command::Train { suite, trees } => train(suite, trees),
        Command::Analyze { source } => analyze(source),
        Command::Verify { artifacts } => verify(&artifacts),
        Command::Report { source, out } => report_cmd(source, out),
        Command::Export { suite, dir } => export(suite, &dir),
        Command::ServeBench {
            suite,
            matrices,
            batches,
            workers,
            shards,
            queue_cap,
            policy,
            pooled,
            plan_cache_cap,
            tune,
            trace_out,
            metrics_out,
            scaling_out,
        } => serve_bench(
            suite, matrices, batches, workers, shards, queue_cap, policy,
            pooled, plan_cache_cap, tune, trace_out, metrics_out,
            scaling_out,
        ),
        Command::Replay {
            suite,
            pattern,
            requests,
            matrices,
            max_batch,
            clients,
            rate,
            seed,
            planner,
            json,
            shards,
            queue_cap,
            policy,
            pooled,
            plan_cache_cap,
            tune,
            tune_policy,
            tune_state,
            trace_out,
            metrics_out,
            scaling_out,
            model,
        } => replay_cmd(ReplayCmd {
            suite,
            pattern,
            requests,
            matrices,
            max_batch,
            clients,
            rate,
            seed,
            planner,
            json,
            shards,
            queue_cap,
            policy,
            pooled,
            plan_cache_cap,
            tune,
            tune_policy,
            tune_state,
            trace_out,
            metrics_out,
            scaling_out,
            model,
        }),
        Command::Check { suite, matrices, seed, quick, hb } => {
            check_cmd(suite, matrices, seed, quick, hb)
        }
        Command::ObsReport {
            baseline,
            current,
            efficiency_drop,
            knee_shift,
            share_drift,
            queue_p95_ms,
            health_baseline,
            health_current,
            recovery_p95_ms,
            shed_rate_drift,
            dwell_drift,
        } => obs_report_cmd(ObsReportCmd {
            baseline,
            current,
            efficiency_drop,
            knee_shift,
            share_drift,
            queue_p95_ms,
            health_baseline,
            health_current,
            recovery_p95_ms,
            shed_rate_drift,
            dwell_drift,
        }),
        Command::Chaos {
            seed,
            scenarios,
            requests,
            matrices,
            shards,
            faults,
            retry_budget,
            canary,
            health_out,
        } => chaos_cmd(
            ft2000_spmv::resil::ChaosConfig {
                seed,
                scenarios,
                requests,
                matrices,
                shards,
                faults,
                retry_budget,
                canary,
            },
            health_out,
        ),
        Command::Info => info(),
    }
}

/// `ft2000-spmv check` — sweep the structural invariant verifier over
/// the corpus, every plan family the planner can emit, the plan
/// cache, the live serve path (validation seam + trace rings), and
/// the deterministic interleaving harness. Exits nonzero on any
/// finding, so CI can gate on it.
fn check_cmd(
    suite: SuiteSpec,
    matrices: usize,
    seed: u64,
    quick: bool,
    hb: bool,
) -> Result<()> {
    use ft2000_spmv::check::{self, interleave, CheckReport, Finding};
    use ft2000_spmv::service::{build_plan_with, PlannedFormat};

    eprintln!("check: registering {matrices} corpus matrices...");
    let plan_cfg = PlanConfig { validate: true, ..PlanConfig::default() };
    let mut reg = MatrixRegistry::new();
    let ids = reg.register_suite(&suite, Some(matrices));
    let engine =
        ServeEngine::pooled(reg, Planner::Heuristic, plan_cfg.clone());
    let n_lanes = engine.pool().map(|p| p.n_workers() + 1).unwrap_or(1);
    let engine = engine.with_trace(std::sync::Arc::new(TraceRecorder::new(
        TraceConfig::on(),
        ClockMode::Wall,
        n_lanes,
    )));

    // Every schedule family the planner can emit, verified per matrix
    // (format structure, partition coverage, memoized schedule).
    let families = [
        Schedule::CsrRowStatic,
        Schedule::CsrRowBalanced,
        Schedule::Csr5Tiles { tile_nnz: plan_cfg.csr5_tile_nnz },
        Schedule::CsrDynamic { chunk: 64 },
        Schedule::SellChunks {
            c: plan_cfg.sell_c,
            sigma: plan_cfg.sell_sigma,
        },
    ];
    let mut report = CheckReport::new();
    for &id in &ids {
        let entry = engine.registry.entry(id);
        report.merge(check::check_csr(&entry.name, &entry.csr));
        for sched in families {
            let plan = build_plan_with(
                &plan_cfg,
                &entry.csr,
                sched,
                plan_cfg.n_threads,
                Vec::new(),
            );
            let subject = format!("{}:{}", entry.name, plan.schedule_name);
            report.merge(check::check_plan(&subject, &plan, &entry.csr));
            match &plan.format {
                PlannedFormat::Csr5(c5) => report.merge(
                    check::check_csr5_vs_csr(&subject, c5, &entry.csr),
                ),
                PlannedFormat::Sell(s) => report.merge(
                    check::check_sell_vs_csr(&subject, s, &entry.csr),
                ),
                PlannedFormat::Csr => {}
            }
        }
        // One request through the live serve path: exercises the
        // `quick_plan_check` dispatch seam and fills the trace rings
        // that are validated below.
        let x = vec![1.0f64; entry.csr.n_cols];
        if let Err(e) = engine.serve_batch(id, &[x.as_slice()]) {
            report.findings.push(Finding {
                subject: entry.name.clone(),
                invariant: "serve-dispatch",
                detail: format!("{e:#}"),
            });
        }
        report.checked += 1;
    }
    report.merge(check::check_plan_cache("plan-cache", &engine.plans));
    if let Some(rec) = engine.trace() {
        for detail in rec.validate() {
            report.findings.push(Finding {
                subject: "serve-trace".into(),
                invariant: "trace-well-formed",
                detail,
            });
        }
        report.checked += 1;
    }

    let icfg = if quick {
        interleave::InterleaveConfig::quick(seed)
    } else {
        interleave::InterleaveConfig::full(seed)
    };
    eprintln!(
        "check: interleaving harness ({} mode, seed {seed:#x})...",
        if quick { "quick" } else { "full" }
    );
    report.merge(interleave::run(&icfg));

    if hb {
        run_hb(seed, quick, &mut report)?;
    }

    if report.is_clean() {
        println!(
            "check: clean — {} invariants over {} matrices x {} plan \
             families, plan cache, serve trace, interleaving harness",
            report.checked,
            ids.len(),
            families.len()
        );
        return Ok(());
    }
    let mut t = Table::new(
        format!("Structural check findings ({})", report.findings.len()),
        &["subject", "invariant", "detail"],
    );
    for f in &report.findings {
        t.row(vec![
            f.subject.clone(),
            f.invariant.to_string(),
            f.detail.clone(),
        ]);
    }
    t.print();
    anyhow::bail!(
        "{} finding(s) across {} checked invariants",
        report.findings.len(),
        report.checked
    )
}

/// `check --hb` — replay the instrumented lock-free core under seeded
/// interleavings, then analyze the captured event logs with the
/// vector-clock happens-before detector: conflicting accesses that no
/// derived edge orders become findings, over-strong orderings become
/// advisories.
#[cfg(feature = "hbcheck")]
fn run_hb(
    seed: u64,
    quick: bool,
    report: &mut ft2000_spmv::check::CheckReport,
) -> Result<()> {
    use ft2000_spmv::check::hb;
    let cfg = if quick {
        hb::HbConfig::quick(seed)
    } else {
        hb::HbConfig::full(seed)
    };
    eprintln!(
        "check: happens-before analysis ({} mode, seed {seed:#x})...",
        if quick { "quick" } else { "full" }
    );
    let run = hb::run(&cfg);
    for a in &run.advice {
        println!("hb advice: {a}");
    }
    println!(
        "hb: {} — {} invariants, {} schedules, {} events, {} sync edges",
        if run.report.is_clean() { "clean" } else { "RACY" },
        run.report.checked,
        run.schedules,
        run.events,
        run.edges,
    );
    report.merge(run.report);
    Ok(())
}

/// Without the `hbcheck` feature the atomics are uninstrumented and
/// there is nothing to capture — fail loudly rather than report a
/// vacuous clean pass.
#[cfg(not(feature = "hbcheck"))]
fn run_hb(
    _seed: u64,
    _quick: bool,
    _report: &mut ft2000_spmv::check::CheckReport,
) -> Result<()> {
    anyhow::bail!(
        "check --hb needs the instrumented build: \
         `cargo run --features hbcheck -- check --hb`"
    )
}

/// Parsed `obs-report` invocation (bundled: the flag list outgrew a
/// readable argument list once the health pair joined the scaling
/// pair).
struct ObsReportCmd {
    baseline: Option<String>,
    current: Option<String>,
    efficiency_drop: f64,
    knee_shift: usize,
    share_drift: f64,
    queue_p95_ms: Option<f64>,
    health_baseline: Option<String>,
    health_current: Option<String>,
    recovery_p95_ms: Option<f64>,
    shed_rate_drift: f64,
    dwell_drift: f64,
}

/// `ft2000-spmv obs-report` — diff snapshot pairs (baseline vs
/// current) into counted regression findings and exit nonzero on any,
/// so CI can gate scalability and fault-handling health the way
/// `check` gates structure. The scaling pair feeds
/// `obs::scaling::compare` (`ft2000.scaling.v1`); the health pair
/// feeds `resil::compare_health` (`ft2000.health.v1`); findings from
/// both merge into one report.
fn obs_report_cmd(cmd: ObsReportCmd) -> Result<()> {
    use ft2000_spmv::obs::scaling::{compare, CompareThresholds};
    use ft2000_spmv::resil::{compare_health, HealthThresholds};
    let read = |path: &str| -> Result<ft2000_spmv::util::json::Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        ft2000_spmv::util::json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))
    };
    let mut report = ft2000_spmv::check::CheckReport::new();
    let mut diffed: Vec<String> = Vec::new();
    if let (Some(b), Some(c)) = (&cmd.baseline, &cmd.current) {
        let th = CompareThresholds {
            efficiency_drop: cmd.efficiency_drop,
            knee_shift: cmd.knee_shift,
            share_drift: cmd.share_drift,
            queue_p95_ms: cmd.queue_p95_ms,
        };
        report.merge(compare(&read(b)?, &read(c)?, &th));
        diffed.push(format!("scaling {b} -> {c}"));
    }
    if let (Some(b), Some(c)) = (&cmd.health_baseline, &cmd.health_current)
    {
        let th = HealthThresholds {
            recovery_p95_ms: cmd.recovery_p95_ms,
            shed_rate_drift: cmd.shed_rate_drift,
            dwell_drift: cmd.dwell_drift,
        };
        report.merge(compare_health(&read(b)?, &read(c)?, &th));
        diffed.push(format!("health {b} -> {c}"));
    }
    if report.is_clean() {
        println!(
            "obs-report: clean — {} invariants hold ({})",
            report.checked,
            diffed.join(", ")
        );
        return Ok(());
    }
    let mut t = Table::new(
        format!("Observability regressions ({})", report.findings.len()),
        &["subject", "invariant", "detail"],
    );
    for f in &report.findings {
        t.row(vec![
            f.subject.clone(),
            f.invariant.to_string(),
            f.detail.clone(),
        ]);
    }
    t.print();
    anyhow::bail!(
        "{} finding(s) across {} checked invariants",
        report.findings.len(),
        report.checked
    )
}

/// `ft2000-spmv chaos` — run the seeded fault-matrix sweep
/// ([`ft2000_spmv::resil::chaos::run`]) and exit nonzero on any
/// finding, so CI can gate graceful degradation the way `check` gates
/// structure. `--health-out` writes the merged `ft2000.health.v1`
/// document for a later `obs-report --health-baseline/--health-current`
/// diff.
fn chaos_cmd(
    cfg: ft2000_spmv::resil::ChaosConfig,
    health_out: Option<String>,
) -> Result<()> {
    eprintln!(
        "chaos: {} scenario(s) x {} steps, {} shards, seed {:#x}{}...",
        cfg.scenarios,
        cfg.requests,
        cfg.shards,
        cfg.seed,
        if cfg.canary { " (canary planted)" } else { "" }
    );
    let out = ft2000_spmv::resil::chaos::run(&cfg);
    if let Some(path) = &health_out {
        std::fs::write(path, out.health.to_string())?;
        eprintln!("wrote {path}");
    }
    if out.report.is_clean() {
        println!(
            "chaos: clean — {} invariants over {} scenario(s), {} requests \
             submitted: none lost or duplicated, every served output \
             bitwise-correct, every fault a counted graceful outcome",
            out.report.checked, out.scenarios, out.submitted
        );
        return Ok(());
    }
    let mut t = Table::new(
        format!("Chaos findings ({})", out.report.findings.len()),
        &["subject", "invariant", "detail"],
    );
    for f in &out.report.findings {
        t.row(vec![
            f.subject.clone(),
            f.invariant.to_string(),
            f.detail.clone(),
        ]);
    }
    t.print();
    anyhow::bail!(
        "{} finding(s) across {} checked invariants",
        out.report.findings.len(),
        out.report.checked
    )
}

/// Wall-clock tuning config of the live `serve-bench --tune` path.
fn live_tune_config() -> AutotuneConfig {
    AutotuneConfig::default()
}

#[allow(clippy::too_many_arguments)]
fn serve_bench(
    suite: SuiteSpec,
    matrices: usize,
    batches: Vec<usize>,
    workers: usize,
    shards: usize,
    queue_cap: usize,
    policy: PlacementPolicy,
    pooled: bool,
    plan_cache_cap: usize,
    tune: bool,
    trace_out: Option<String>,
    metrics_out: Option<String>,
    scaling_out: Option<String>,
) -> Result<()> {
    eprintln!("registering {matrices} corpus matrices...");
    let plan_cfg =
        PlanConfig { cache_cap: plan_cache_cap, ..PlanConfig::default() };
    let mut reg = MatrixRegistry::new();
    let ids = reg.register_suite(&suite, Some(matrices));
    let engine = ServeEngine::with_mode(
        pooled,
        reg,
        Planner::Heuristic,
        plan_cfg.clone(),
    );
    let mode = if pooled { "pool" } else { "spawn" };

    // --- batched SpMM vs repeated single-vector SpMV -----------------
    let bench_cfg = BenchConfig {
        warmup_iters: 1,
        min_iters: 3,
        max_iters: 40,
        target_rel_ci: 0.1,
        max_seconds: 1.5,
    };
    let mut t = Table::new(
        format!(
            "Batched SpMM vs repeated single-vector SpMV \
             (cached plans, {mode} dispatch)"
        ),
        &["matrix", "nnz", "batch", "spmm Gflops", "spmv Gflops", "win"],
    );
    // The largest matrices: the memory-bound regime where streaming A
    // once per batch pays most.
    let mut chosen = ids.clone();
    chosen.sort_by_key(|&id| {
        std::cmp::Reverse(engine.registry.entry(id).csr.nnz())
    });
    chosen.dedup();
    chosen.truncate(3);
    for &id in &chosen {
        let entry = engine.registry.entry(id);
        let (plan, _) = engine.plans.plan_for(entry.fingerprint, &entry.csr);
        let x = vec![1.0f64; entry.csr.n_cols];
        let nnz = entry.csr.nnz();
        for &b in &batches {
            let xs_refs: Vec<&[f64]> = (0..b).map(|_| x.as_slice()).collect();
            let packed = exec::pack_vectors(&xs_refs);
            let spmm = bench("spmm", &bench_cfg, || {
                black_box(plan.execute_batch_on(
                    &entry.csr,
                    &packed,
                    b,
                    engine.pool(),
                ));
            });
            let spmv = bench("spmv", &bench_cfg, || {
                for _ in 0..b {
                    black_box(plan.execute_on(&entry.csr, &x, engine.pool()));
                }
            });
            let flops = 2.0 * nnz as f64 * b as f64;
            t.row(vec![
                entry.name.clone(),
                nnz.to_string(),
                b.to_string(),
                format!("{:.3}", flops / spmm.mean_s / 1e9),
                format!("{:.3}", flops / spmv.mean_s / 1e9),
                format!("{:.2}x", spmv.mean_s / spmm.mean_s),
            ]);
        }
    }
    t.print();

    // --- live throughput ---------------------------------------------
    // Fresh registry so the report's cache/telemetry counters reflect
    // only the live run, not the microbench warmup above. One poison
    // request (unregistered matrix id) rides along in both modes: it
    // must surface as an error/rejection in the report, never abort
    // the run.
    let mut reg = MatrixRegistry::new();
    let ids = reg.register_suite(&suite, Some(matrices));
    let n_req = 512;
    let wl = WorkloadSpec {
        requests: n_req,
        popularity: Popularity::Zipf { s: 1.2 },
        arrivals: Arrivals::Closed { clients: workers },
        seed: 0xBEEF,
    };
    let seq = wl.generate(ids.len());
    let poison_id = usize::MAX;
    // One shared input per matrix keeps the queues' memory flat.
    let inputs: std::collections::HashMap<usize, std::sync::Arc<Vec<f64>>> =
        ids.iter()
            .map(|&id| {
                let n = reg.entry(id).csr.n_cols;
                (id, std::sync::Arc::new(vec![1.0f64; n]))
            })
            .collect();
    if shards <= 1 {
        // Legacy path: one global queue, one undifferentiated worker
        // set — the topology-blind baseline of the A/B.
        let engine = ServeEngine::with_mode(
            pooled,
            reg,
            Planner::Heuristic,
            plan_cfg.clone(),
        );
        let engine = if tune {
            engine.with_tuner(live_tune_config())
        } else {
            engine
        };
        let engine = if trace_out.is_some() || metrics_out.is_some() {
            // Lane 0 is the dispatcher; pool workers get their own
            // lanes when pooled dispatch is on.
            let n_lanes =
                engine.pool().map(|p| p.n_workers() + 1).unwrap_or(1);
            engine.with_trace(std::sync::Arc::new(TraceRecorder::new(
                TraceConfig::on(),
                ClockMode::Wall,
                n_lanes,
            )))
        } else {
            engine
        };
        eprintln!(
            "live global queue ({mode} dispatch): {n_req} zipf requests, \
             {workers} workers..."
        );
        let queue = RequestQueue::bounded(queue_cap);
        let t0 = std::time::Instant::now();
        let served = std::thread::scope(|s| {
            s.spawn(|| {
                for (i, r) in seq.iter().enumerate() {
                    if i == n_req / 2 {
                        let _ = queue.try_push(Request::new(
                            poison_id,
                            vec![1.0; 8],
                        ));
                    }
                    let id = ids[r.matrix_idx];
                    if queue
                        .try_push(Request::new(id, inputs[&id].clone()))
                        .is_err()
                    {
                        engine.telemetry.record_rejected(1);
                    }
                }
                queue.close();
            });
            serve_queue(&engine, &queue, workers, 16)
        });
        let wall = t0.elapsed().as_secs_f64();
        let stats = engine.telemetry.snapshot();
        let (hits, misses) = engine.plans.stats();
        service::telemetry::report_table(
            "Live global-queue serving report (wall clock)",
            &stats,
            hits,
            misses,
            wall,
        )
        .print();
        service::telemetry::batch_histogram_table(&stats).print();
        if let Some(t) = engine.tuner() {
            autotune_table(&t.summaries()).print();
            let (promos, demos) = t.totals();
            eprintln!(
                "autotune: {} tuners, {promos} promotions, {demos} \
                 demotions, {} observations logged",
                t.tuner_count(),
                t.dataset_len()
            );
        }
        if let Some(rec) = engine.trace() {
            rec.flame_table().print();
        }
        if let Some(path) = &trace_out {
            let rec = engine.trace().expect("tracing enabled above");
            std::fs::write(path, rec.export_chrome().to_string())?;
            eprintln!("wrote {path}");
        }
        if let Some(path) = &metrics_out {
            std::fs::write(path, engine.metrics_snapshot().to_string())?;
            eprintln!("wrote {path}");
        }
        engine.scaling().table().print();
        if let Some(path) = &scaling_out {
            std::fs::write(path, engine.scaling_snapshot().to_string())?;
            eprintln!("wrote {path}");
        }
        eprintln!("served {served} requests in {wall:.3}s");
    } else {
        // Sharded path: one shard per modeled panel, matrices placed
        // by expected request mass (Zipf rank), hot ones replicated.
        let registry = std::sync::Arc::new(reg);
        let weights = wl.popularity.placement_weights(&ids, registry.len());
        let cfg = ShardConfig {
            shards,
            queue_cap,
            workers_per_shard: workers,
            max_batch: 16,
            deadline_ms: 0.0,
            policy,
            pooled,
            tune: if tune { Some(live_tune_config()) } else { None },
            trace: if trace_out.is_some() || metrics_out.is_some() {
                Some(TraceConfig::on())
            } else {
                None
            },
        };
        let server = ShardedServer::with_weights(
            registry.clone(),
            Planner::Heuristic,
            plan_cfg.clone(),
            cfg,
            &weights,
        );
        eprintln!(
            "live sharded serving ({mode} dispatch): {n_req} zipf requests, \
             {shards} shards x {workers} workers, queue cap {queue_cap}..."
        );
        let t0 = std::time::Instant::now();
        let served = std::thread::scope(|s| {
            s.spawn(|| {
                for (i, r) in seq.iter().enumerate() {
                    if i == n_req / 2 {
                        server.submit(Request::new(poison_id, vec![1.0; 8]));
                    }
                    let id = ids[r.matrix_idx];
                    server.submit(Request::new(id, inputs[&id].clone()));
                }
                server.close();
            });
            server.serve()
        });
        let wall = t0.elapsed().as_secs_f64();
        service::telemetry::shard_table(&server.snapshots(wall)).print();
        let merged = server.merged_stats();
        let (hits, misses) = server.cache_totals();
        service::telemetry::report_table(
            format!("Sharded serving report ({shards} shards, wall clock)"),
            &merged,
            hits,
            misses,
            wall,
        )
        .print();
        service::telemetry::batch_histogram_table(&merged).print();
        if tune {
            autotune_table(&server.autotune_summaries()).print();
            let (promos, demos) = server.autotune_totals();
            eprintln!(
                "autotune: {promos} promotions, {demos} demotions \
                 across {shards} shards"
            );
        }
        if let Some(path) = &trace_out {
            std::fs::write(path, server.export_chrome().to_string())?;
            eprintln!("wrote {path}");
        }
        if let Some(path) = &metrics_out {
            std::fs::write(
                path,
                server.metrics_snapshot(wall).to_string(),
            )?;
            eprintln!("wrote {path}");
        }
        if let Some(path) = &scaling_out {
            std::fs::write(path, server.scaling_snapshot().to_string())?;
            eprintln!("wrote {path}");
        }
        eprintln!(
            "served {served} requests in {wall:.3}s \
             ({} rejected, {} errors)",
            merged.rejected, merged.errors
        );
    }
    Ok(())
}

/// Parsed `replay` invocation (bundled: the flag list outgrew a
/// readable argument list).
struct ReplayCmd {
    suite: SuiteSpec,
    pattern: TrafficPattern,
    requests: usize,
    matrices: usize,
    max_batch: usize,
    clients: usize,
    rate: f64,
    seed: u64,
    planner: PlannerKind,
    json: Option<String>,
    shards: usize,
    queue_cap: usize,
    policy: PlacementPolicy,
    pooled: bool,
    plan_cache_cap: usize,
    tune: bool,
    tune_policy: TunePolicyKind,
    tune_state: Option<String>,
    trace_out: Option<String>,
    metrics_out: Option<String>,
    scaling_out: Option<String>,
    model: bool,
}

/// Virtual-clock tuning config of the `replay --tune` path: the cost
/// model feeds observations, so the run is deterministic per seed.
fn replay_tune_config(cmd: &ReplayCmd) -> AutotuneConfig {
    AutotuneConfig {
        policy: match cmd.tune_policy {
            TunePolicyKind::Epsilon => Policy::EpsilonGreedy { epsilon: 0.1 },
            TunePolicyKind::Ucb => Policy::Ucb1 { c: 1.0 },
        },
        wall_clock: false,
        seed: cmd.seed,
        ..AutotuneConfig::default()
    }
}

fn replay_cmd(cmd: ReplayCmd) -> Result<()> {
    eprintln!("registering up to {} corpus matrices...", cmd.matrices);
    let mut reg = MatrixRegistry::new();
    let ids = reg.register_suite(&cmd.suite, Some(cmd.matrices));
    eprintln!(
        "registered {} matrices ({} nonzeros total)",
        reg.len(),
        reg.total_nnz()
    );
    let planner = match cmd.planner {
        PlannerKind::Heuristic => Planner::Heuristic,
        PlannerKind::Learned => {
            eprintln!(
                "training the learned format selector on the tiny suite..."
            );
            Planner::train(&SuiteSpec::tiny())
        }
    };
    let popularity = match cmd.pattern {
        TrafficPattern::Uniform => Popularity::Uniform,
        TrafficPattern::Zipf | TrafficPattern::Bursty => {
            Popularity::Zipf { s: 1.2 }
        }
    };
    let arrivals = if cmd.clients > 0 {
        Arrivals::Closed { clients: cmd.clients }
    } else if cmd.pattern == TrafficPattern::Bursty {
        Arrivals::Bursty {
            rate: cmd.rate,
            burst: 8.0,
            period_s: 0.5,
            duty: 0.3,
        }
    } else {
        Arrivals::Open { rate: cmd.rate }
    };
    let requests = cmd.requests;
    let wspec =
        WorkloadSpec { requests, popularity, arrivals, seed: cmd.seed };
    let plan_cfg = PlanConfig {
        cache_cap: cmd.plan_cache_cap,
        ..PlanConfig::default()
    };
    let rcfg = ReplayConfig {
        max_batch: cmd.max_batch,
        queue_cap: cmd.queue_cap,
        execute: !cmd.model,
        pooled: cmd.pooled,
        tune: if cmd.tune && cmd.shards > 1 {
            Some(replay_tune_config(&cmd))
        } else {
            None
        },
        trace: if cmd.trace_out.is_some() || cmd.metrics_out.is_some() {
            Some(TraceConfig::on())
        } else {
            None
        },
        ..Default::default()
    };
    eprintln!(
        "replaying {requests} requests ({arrivals:?}, {popularity:?}, \
         seed {:#x}, {} shard(s), {} dispatch{}{})...",
        cmd.seed,
        cmd.shards,
        if cmd.pooled { "pool" } else { "spawn" },
        if cmd.tune { ", tuned" } else { "" },
        if cmd.model { ", model only" } else { "" }
    );
    if cmd.shards > 1 {
        if cmd.tune_state.is_some() {
            eprintln!(
                "note: --tune-state applies to single-shard replays only \
                 (per-shard tuners are built by the harness); ignoring it"
            );
        }
        let registry = std::sync::Arc::new(reg);
        let report = service::replay_sharded(
            registry,
            &planner,
            &plan_cfg,
            &ids,
            &wspec,
            &rcfg,
            cmd.shards,
            cmd.policy,
        )?;
        report.print();
        if let Some(path) = cmd.json {
            std::fs::write(&path, report.to_json().to_string())?;
            eprintln!("wrote {path}");
        }
        if let Some(path) = &cmd.trace_out {
            std::fs::write(path, report.export_chrome().to_string())?;
            eprintln!("wrote {path}");
        }
        if let Some(path) = &cmd.metrics_out {
            std::fs::write(path, report.metrics_json().to_string())?;
            eprintln!("wrote {path}");
        }
        if let Some(path) = &cmd.scaling_out {
            std::fs::write(path, report.scaling.to_string())?;
            eprintln!("wrote {path}");
        }
        return Ok(());
    }
    if !cmd.tune && cmd.tune_state.is_some() {
        eprintln!("note: --tune-state does nothing without --tune");
    }
    let engine =
        ServeEngine::with_mode(cmd.pooled, reg, planner, plan_cfg.clone());
    let engine = if cmd.tune {
        let mut tuner =
            Autotuner::new(replay_tune_config(&cmd), plan_cfg.clone());
        if let Some(path) = &cmd.tune_state {
            match std::fs::read_to_string(path) {
                Ok(text) => match ft2000_spmv::util::json::parse(&text) {
                    Ok(snapshot) => {
                        tuner = tuner.warm_start(&snapshot);
                        eprintln!("warm-started tuning state from {path}");
                    }
                    Err(e) => eprintln!(
                        "ignoring unparsable tune state {path}: {e}"
                    ),
                },
                Err(_) => {
                    eprintln!("no tune state at {path} yet (cold start)")
                }
            }
        }
        engine.with_tuner_state(tuner)
    } else {
        engine
    };
    let engine = if cmd.trace_out.is_some() || cmd.metrics_out.is_some() {
        // Replay timestamps spans on the virtual clock so the Chrome
        // trace lines up with the simulated timeline, not wall time.
        let n_lanes =
            engine.pool().map(|p| p.n_workers() + 1).unwrap_or(1);
        engine.with_trace(std::sync::Arc::new(TraceRecorder::new(
            TraceConfig::on(),
            ClockMode::Virtual,
            n_lanes,
        )))
    } else {
        engine
    };
    let report = service::replay(&engine, &ids, &wspec, &rcfg)?;
    report.print();
    println!(
        "plan cache: {} plans built ({} planner), hit rate {:.1}%",
        engine.plans.len(),
        engine.plans.planner_name(),
        100.0 * report.hit_rate()
    );
    if let Some(t) = engine.tuner() {
        let (promos, demos) = t.totals();
        println!(
            "autotune: {} tuners, {promos} promotions, {demos} demotions, \
             {} observations logged ({} policy)",
            t.tuner_count(),
            t.dataset_len(),
            t.config().policy.name()
        );
        if let Some(path) = &cmd.tune_state {
            std::fs::write(path, t.to_json().to_string())?;
            eprintln!("wrote tuning state to {path}");
        }
    }
    if let Some(path) = cmd.json {
        std::fs::write(&path, report.to_json().to_string())?;
        eprintln!("wrote {path}");
    }
    if let Some(rec) = engine.trace() {
        rec.flame_table().print();
    }
    if let Some(path) = &cmd.trace_out {
        let rec = engine.trace().expect("tracing enabled above");
        std::fs::write(path, rec.export_chrome().to_string())?;
        eprintln!("wrote {path}");
    }
    if let Some(path) = &cmd.metrics_out {
        std::fs::write(path, engine.metrics_snapshot().to_string())?;
        eprintln!("wrote {path}");
    }
    engine.scaling().table().print();
    if let Some(path) = &cmd.scaling_out {
        std::fs::write(path, engine.scaling_snapshot().to_string())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn sweep(
    suite: SuiteSpec,
    schedule: Schedule,
    placement: Placement,
    threads: Vec<usize>,
    csv: Option<String>,
) -> Result<()> {
    let cfg = ProfileConfig {
        schedule,
        placement,
        threads,
        ..Default::default()
    };
    eprintln!(
        "sweeping {} matrices ({} workers)...",
        suite.total(),
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    let campaign = Campaign::new(suite, cfg);
    let profiles = campaign.run();
    report::table2_average_speedups(&profiles).print();
    report::fig4_distribution(&profiles).print();
    report::factor_correlations(&profiles).print();
    if let Some(path) = csv {
        let mut f = std::fs::File::create(&path)?;
        report::write_csv(&mut f, &profiles)?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn train(suite: SuiteSpec, trees: usize) -> Result<()> {
    let campaign = Campaign::new(suite, ProfileConfig::default());
    eprintln!("profiling {} matrices...", campaign.spec.total());
    let profiles = campaign.run();
    let data = build_dataset(&profiles);
    // The paper trains on 90% (§4.2: analysis, not prediction).
    let (train, test) = data.split(0.9, 0x5EED);
    let forest = Forest::fit(
        &train,
        ForestParams { n_trees: trees, ..Default::default() },
    );
    let mut t = Table::new(
        "Feature importances (regression forest)",
        &["feature", "importance"],
    );
    for (name, imp) in forest.ranked_features() {
        t.row(vec![name, format!("{imp:.4}")]);
    }
    t.print();
    println!(
        "train mse = {:.4}, held-out mse = {:.4} ({} train / {} test)\n",
        forest.mse(&train),
        forest.mse(&test),
        train.len(),
        test.len()
    );
    println!("Fig 5 — a tree picked from the regression forest:\n");
    println!("{}", forest.representative_tree(&train).render());
    Ok(())
}

fn analyze(source: MatrixSource) -> Result<()> {
    let (name, csr) = load(source)?;
    let profile = profile_matrix(&csr, &name, &ProfileConfig::default());
    let mut t = Table::new(
        format!("Profile of {name} (FT-2000+, one core-group, CSR static)"),
        &["metric", "value"],
    );
    t.row(vec!["rows".into(), profile.features.n_rows.to_string()]);
    t.row(vec!["nnz".into(), profile.features.nnz.to_string()]);
    t.row(vec![
        "nnz_avg".into(),
        format!("{:.2}", profile.features.nnz_avg),
    ]);
    t.row(vec![
        "nnz_var".into(),
        format!("{:.3}", profile.features.nnz_var),
    ]);
    t.row(vec!["job_var".into(), format!("{:.3}", profile.derived.job_var)]);
    t.row(vec![
        "L2_DCMR_change".into(),
        format!("{:+.4}", profile.derived.l2_dcmr_change),
    ]);
    for (i, nt) in profile.thread_counts.iter().enumerate() {
        t.row(vec![
            format!("speedup {nt}t"),
            format!(
                "{:.3}x ({:.3} Gflops)",
                profile.speedups[i], profile.gflops[i]
            ),
        ]);
    }
    t.print();
    for line in ft2000_spmv::coordinator::advisor::advise(&csr, &profile) {
        println!("advice: {line}");
    }
    Ok(())
}

fn load(source: MatrixSource) -> Result<(String, Csr)> {
    match source {
        MatrixSource::Named(m) => Ok((m.name().to_string(), m.generate())),
        MatrixSource::MatrixMarket(path) => {
            let f = std::fs::File::open(&path)?;
            Ok((path, mm::read_csr(f)?))
        }
    }
}

fn report_cmd(source: MatrixSource, out: Option<String>) -> Result<()> {
    let (name, csr) = load(source)?;
    let text =
        ft2000_spmv::coordinator::matrix_report::matrix_report(&csr, &name);
    match out {
        Some(path) => {
            std::fs::write(&path, &text)?;
            eprintln!("wrote {path}");
        }
        None => println!("{text}"),
    }
    Ok(())
}

fn export(suite: SuiteSpec, dir: &str) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let entries = suite.entries();
    for e in &entries {
        let m = suite.materialize(e);
        let path = format!("{dir}/{}.mtx", e.name);
        let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
        mm::write_csr(&mut f, &m.csr).map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    eprintln!("exported {} matrices to {dir}", entries.len());
    Ok(())
}

fn verify(artifacts: &str) -> Result<()> {
    use ft2000_spmv::util::rng::Pcg32;
    let rt = Runtime::new(artifacts)?;
    println!("PJRT platform: {}", rt.platform());
    let mut rng = Pcg32::new(42);
    let mut failures = 0;
    for (name, csr) in [
        (
            "banded-1k",
            ft2000_spmv::corpus::generators::banded(1000, 7, &mut rng),
        ),
        (
            "random-2k",
            ft2000_spmv::corpus::generators::random_uniform(
                2000, 12, &mut rng,
            ),
        ),
        (
            "skewed-seg",
            ft2000_spmv::corpus::generators::dense_row_block(
                1500, 12_000, &mut rng,
            ),
        ),
    ] {
        let x: Vec<f64> = (0..csr.n_cols).map(|_| rng.gen_f64()).collect();
        let mut want = vec![0.0; csr.n_rows];
        csr.spmv(&x, &mut want);
        let got = rt.spmv(&csr, &x)?;
        let max_err = want
            .iter()
            .zip(&got)
            .map(|(a, b)| (a - b).abs() / (1.0 + a.abs()))
            .fold(0.0, f64::max);
        let ok = max_err < 1e-4;
        println!(
            "{name:<12} rows={:<6} nnz={:<8} max_rel_err={max_err:.2e} {}",
            csr.n_rows,
            csr.nnz(),
            if ok { "OK" } else { "FAIL" }
        );
        if !ok {
            failures += 1;
        }
    }
    if failures > 0 {
        anyhow::bail!("{failures} artifact checks failed");
    }
    println!("runtime verification OK (pallas kernels == native executor)");
    Ok(())
}

fn info() -> Result<()> {
    for topo in [Topology::ft2000plus(), Topology::xeon_e5_2692()] {
        let mut t =
            Table::new(format!("Topology: {}", topo.name), &["param", "value"]);
        t.row(vec!["cores".into(), topo.cores.to_string()]);
        t.row(vec!["freq".into(), format!("{} GHz", topo.freq_ghz)]);
        t.row(vec![
            "L1d".into(),
            format!("{} KB x{}", topo.l1.size_bytes / 1024, topo.l1.ways),
        ]);
        t.row(vec![
            "L2".into(),
            format!(
                "{} MB x{} shared by {} cores",
                topo.l2.size_bytes / (1024 * 1024),
                topo.l2.ways,
                topo.l2_group_cores
            ),
        ]);
        t.row(vec![
            "mem domain".into(),
            format!(
                "{} GB/s per {} cores",
                topo.bw_domain_gbs, topo.cores_per_mem_domain
            ),
        ]);
        t.print();
    }
    Ok(())
}
