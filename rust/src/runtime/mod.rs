//! PJRT runtime: loads the AOT-compiled Pallas/JAX SpMV artifacts
//! (`artifacts/*.hlo.txt`) and executes them from rust.
//!
//! Python runs only at `make artifacts`; this module is the entire
//! request-path compute story. Interchange is HLO *text* (jax >= 0.5
//! emits 64-bit-id protos that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids — see /opt/xla-example/README.md).
//!
//! Artifacts are shape-monomorphic *buckets* (`manifest.json`); the
//! [`Registry`] picks the smallest bucket a matrix fits after padding,
//! pads the ELL/seg buffers, executes, and un-pads the result.
//!
//! The PJRT client lives behind the `pjrt` cargo feature (it needs a
//! local `xla` bindings crate that is not on crates.io). The default
//! build substitutes a native f32 interpreter with identical bucket
//! routing, padding, and error semantics.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::sparse::{Csr, Ell};
use crate::util::json::{self, Json};

/// Metadata of one AOT artifact (mirror of aot.py's manifest schema).
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: ArtifactKind,
    pub rows: usize,
    pub n: usize,
    /// ELL/power: padded row width.
    pub k: usize,
    /// seg: padded nonzero count.
    pub nnz: usize,
    /// spmm: dense vector-block width.
    pub v: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    Ell,
    Seg,
    Power,
    Spmm,
}

/// The artifact catalogue parsed from `manifest.json`.
#[derive(Clone, Debug)]
pub struct Registry {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Registry {
    pub fn load(dir: impl AsRef<Path>) -> Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(
            || format!("reading {} (run `make artifacts`)", manifest_path.display()),
        )?;
        let doc = json::parse(&text).context("parsing manifest.json")?;
        if doc.get("format").and_then(Json::as_str) != Some("hlo-text") {
            bail!("unsupported manifest format");
        }
        let mut artifacts = Vec::new();
        for a in doc
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let get_s = |k: &str| -> Result<String> {
                Ok(a.get(k)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact missing {k}"))?
                    .to_string())
            };
            let get_n =
                |k: &str| a.get(k).and_then(Json::as_usize).unwrap_or(0);
            let kind = match get_s("kind")?.as_str() {
                "ell" => ArtifactKind::Ell,
                "seg" => ArtifactKind::Seg,
                "power" => ArtifactKind::Power,
                "spmm" => ArtifactKind::Spmm,
                other => bail!("unknown artifact kind {other}"),
            };
            artifacts.push(ArtifactMeta {
                name: get_s("name")?,
                file: get_s("file")?,
                kind,
                rows: get_n("rows"),
                n: get_n("n"),
                k: get_n("k"),
                nnz: get_n("nnz"),
                v: get_n("v"),
            });
        }
        Ok(Registry { dir, artifacts })
    }

    /// Smallest ELL bucket that fits `(rows, k)`.
    pub fn pick_ell(&self, rows: usize, k: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.kind == ArtifactKind::Ell && a.rows >= rows && a.k >= k
            })
            .min_by_key(|a| a.rows * a.k)
    }

    /// Smallest seg bucket that fits `(nnz, rows)`.
    pub fn pick_seg(&self, nnz: usize, rows: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.kind == ArtifactKind::Seg && a.nnz >= nnz && a.rows >= rows
            })
            .min_by_key(|a| a.nnz)
    }

    /// Smallest SpMM bucket fitting `(rows, k, v)`.
    pub fn pick_spmm(
        &self,
        rows: usize,
        k: usize,
        v: usize,
    ) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.kind == ArtifactKind::Spmm
                    && a.rows >= rows
                    && a.k >= k
                    && a.v >= v
            })
            .min_by_key(|a| a.rows * a.k * a.v)
    }

    pub fn pick_power(&self, rows: usize, k: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.kind == ArtifactKind::Power && a.rows >= rows && a.k >= k
            })
            .min_by_key(|a| a.rows * a.k)
    }
}

/// A loaded + compiled artifact, ready to execute.
#[cfg(feature = "pjrt")]
pub struct Compiled {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: client + lazily compiled executables.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    pub registry: Registry,
    client: xla::PjRtClient,
    compiled: std::cell::RefCell<
        std::collections::HashMap<String, std::rc::Rc<Compiled>>,
    >,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create a CPU PJRT client over the artifact directory.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let registry = Registry::load(artifact_dir)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt: {e:?}"))?;
        Ok(Runtime {
            registry,
            client,
            compiled: Default::default(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&self, meta: &ArtifactMeta) -> Result<std::rc::Rc<Compiled>> {
        if let Some(c) = self.compiled.borrow().get(&meta.name) {
            return Ok(c.clone());
        }
        let path = self.registry.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", meta.name))?;
        let c = std::rc::Rc::new(Compiled { meta: meta.clone(), exe });
        self.compiled.borrow_mut().insert(meta.name.clone(), c.clone());
        Ok(c)
    }

    /// y = A x through the ELL Pallas kernel. `x.len()` must equal
    /// `ell.n_cols`; the matrix must fit an ELL bucket.
    pub fn spmv_ell(&self, ell: &Ell, x: &[f64]) -> Result<Vec<f64>> {
        let meta = self
            .registry
            .pick_ell(ell.n_rows, ell.k)
            .ok_or_else(|| {
                anyhow!(
                    "no ELL bucket fits rows={} k={}",
                    ell.n_rows,
                    ell.k
                )
            })?
            .clone();
        let c = self.compile(&meta)?;
        let (cols, data) = ell
            .to_bucket_buffers(meta.rows, meta.k)
            .ok_or_else(|| anyhow!("bucket pack failed"))?;
        let mut xf = vec![0.0f32; meta.n];
        for (i, &v) in x.iter().enumerate() {
            xf[i] = v as f32;
        }
        let lit_cols = xla::Literal::vec1(&cols)
            .reshape(&[meta.rows as i64, meta.k as i64])
            .map_err(wrap)?;
        let lit_data = xla::Literal::vec1(&data)
            .reshape(&[meta.rows as i64, meta.k as i64])
            .map_err(wrap)?;
        let lit_x = xla::Literal::vec1(&xf);
        let out = c
            .exe
            .execute::<xla::Literal>(&[lit_cols, lit_data, lit_x])
            .map_err(wrap)?[0][0]
            .to_literal_sync()
            .map_err(wrap)?;
        let y = out.to_tuple1().map_err(wrap)?.to_vec::<f32>().map_err(wrap)?;
        Ok(y[..ell.n_rows].iter().map(|&v| v as f64).collect())
    }

    /// y = A x through the segmented (CSR5-style) Pallas kernel —
    /// handles matrices whose max row width makes ELL impractical.
    pub fn spmv_seg(&self, csr: &Csr, x: &[f64]) -> Result<Vec<f64>> {
        let nnz = csr.nnz();
        let meta = self
            .registry
            .pick_seg(nnz, csr.n_rows)
            .ok_or_else(|| {
                anyhow!("no seg bucket fits nnz={nnz} rows={}", csr.n_rows)
            })?
            .clone();
        let c = self.compile(&meta)?;
        let mut cols = vec![0i32; meta.nnz];
        let mut rows = vec![0i32; meta.nnz];
        let mut data = vec![0.0f32; meta.nnz];
        let mut i = 0usize;
        for r in 0..csr.n_rows {
            let (rc, rv) = csr.row(r);
            for (cc, vv) in rc.iter().zip(rv) {
                cols[i] = *cc as i32;
                rows[i] = r as i32;
                data[i] = *vv as f32;
                i += 1;
            }
        }
        let mut xf = vec![0.0f32; meta.n];
        for (j, &v) in x.iter().enumerate() {
            xf[j] = v as f32;
        }
        let out = c
            .exe
            .execute::<xla::Literal>(&[
                xla::Literal::vec1(&cols),
                xla::Literal::vec1(&rows),
                xla::Literal::vec1(&data),
                xla::Literal::vec1(&xf),
            ])
            .map_err(wrap)?[0][0]
            .to_literal_sync()
            .map_err(wrap)?;
        let y = out.to_tuple1().map_err(wrap)?.to_vec::<f32>().map_err(wrap)?;
        Ok(y[..csr.n_rows].iter().map(|&v| v as f64).collect())
    }

    /// Four normalized power-iteration steps + Rayleigh quotient —
    /// the composed L2 graph (quickstart demo).
    pub fn power_iter(&self, ell: &Ell, x0: &[f64]) -> Result<(Vec<f64>, f64)> {
        let meta = self
            .registry
            .pick_power(ell.n_rows, ell.k)
            .ok_or_else(|| {
                anyhow!(
                    "no power bucket fits rows={} k={}",
                    ell.n_rows,
                    ell.k
                )
            })?
            .clone();
        let c = self.compile(&meta)?;
        let (cols, data) = ell
            .to_bucket_buffers(meta.rows, meta.k)
            .ok_or_else(|| anyhow!("bucket pack failed"))?;
        let mut xf = vec![0.0f32; meta.n];
        for (i, &v) in x0.iter().enumerate() {
            xf[i] = v as f32;
        }
        let out = c
            .exe
            .execute::<xla::Literal>(&[
                xla::Literal::vec1(&cols)
                    .reshape(&[meta.rows as i64, meta.k as i64])
                    .map_err(wrap)?,
                xla::Literal::vec1(&data)
                    .reshape(&[meta.rows as i64, meta.k as i64])
                    .map_err(wrap)?,
                xla::Literal::vec1(&xf),
            ])
            .map_err(wrap)?[0][0]
            .to_literal_sync()
            .map_err(wrap)?;
        let (v, lam) = out.to_tuple2().map_err(wrap)?;
        let vf = v.to_vec::<f32>().map_err(wrap)?;
        let lamf = lam.to_vec::<f32>().map_err(wrap)?;
        Ok((
            vf[..ell.n_rows].iter().map(|&x| x as f64).collect(),
            lamf.first().copied().unwrap_or(0.0) as f64,
        ))
    }

    /// Y = A X through the ELL SpMM kernel: `x` is column-major-free —
    /// pass `vectors` as a slice of `v` vectors, each `n_cols` long.
    pub fn spmm_ell(
        &self,
        ell: &Ell,
        vectors: &[Vec<f64>],
    ) -> Result<Vec<Vec<f64>>> {
        let v = vectors.len();
        anyhow::ensure!(v > 0, "need at least one vector");
        for x in vectors {
            anyhow::ensure!(x.len() == ell.n_cols, "vector length mismatch");
        }
        let meta = self
            .registry
            .pick_spmm(ell.n_rows, ell.k, v)
            .ok_or_else(|| {
                anyhow!(
                    "no SpMM bucket fits rows={} k={} v={v}",
                    ell.n_rows,
                    ell.k
                )
            })?
            .clone();
        let c = self.compile(&meta)?;
        let (cols, data) = ell
            .to_bucket_buffers(meta.rows, meta.k)
            .ok_or_else(|| anyhow!("bucket pack failed"))?;
        // Row-major [n][v] block, zero-padded.
        let mut xf = vec![0.0f32; meta.n * meta.v];
        for (j, x) in vectors.iter().enumerate() {
            for (i, &val) in x.iter().enumerate() {
                xf[i * meta.v + j] = val as f32;
            }
        }
        let out = c
            .exe
            .execute::<xla::Literal>(&[
                xla::Literal::vec1(&cols)
                    .reshape(&[meta.rows as i64, meta.k as i64])
                    .map_err(wrap)?,
                xla::Literal::vec1(&data)
                    .reshape(&[meta.rows as i64, meta.k as i64])
                    .map_err(wrap)?,
                xla::Literal::vec1(&xf)
                    .reshape(&[meta.n as i64, meta.v as i64])
                    .map_err(wrap)?,
            ])
            .map_err(wrap)?[0][0]
            .to_literal_sync()
            .map_err(wrap)?;
        let y =
            out.to_tuple1().map_err(wrap)?.to_vec::<f32>().map_err(wrap)?;
        // Un-pad into per-vector outputs.
        let mut result = vec![vec![0.0f64; ell.n_rows]; v];
        for r in 0..ell.n_rows {
            for (j, out_j) in result.iter_mut().enumerate() {
                out_j[r] = y[r * meta.v + j] as f64;
            }
        }
        Ok(result)
    }

    /// Route a CSR matrix to the best kernel: ELL when padding is
    /// acceptable, the segmented kernel otherwise (the exdata_1-style
    /// pathologies).
    pub fn spmv(&self, csr: &Csr, x: &[f64]) -> Result<Vec<f64>> {
        let k = csr.max_row_nnz();
        let dense_ok = self.registry.pick_ell(csr.n_rows, k).is_some();
        if dense_ok && k > 0 {
            let ell = Ell::from_csr(csr, None)
                .map_err(|e| anyhow!("ell conversion: {e}"))?;
            self.spmv_ell(&ell, x)
        } else {
            self.spmv_seg(csr, x)
        }
    }
}

#[cfg(feature = "pjrt")]
fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e:?}")
}

/// Native fallback runtime (built without the `pjrt` feature — the
/// default in environments without the local `xla` bindings crate).
///
/// Routes through the same [`Registry`] buckets, applies the same
/// padding rules, and accumulates in f32 — so results match the PJRT
/// artifact path to the tolerances the integration tests already use,
/// and "no bucket fits" errors are identical. Build with
/// `--features pjrt` (after adding the local `xla` path dependency to
/// Cargo.toml) to dispatch to a real PJRT client instead.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    pub registry: Registry,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        Ok(Runtime { registry: Registry::load(artifact_dir)? })
    }

    pub fn platform(&self) -> String {
        "native-fallback (f32 interpreter; enable the `pjrt` feature for PJRT)"
            .into()
    }

    /// f32 ELL SpMV over the raw (unpadded) ELL buffers.
    fn ell_spmv_f32(ell: &Ell, xf: &[f32], y: &mut [f32]) {
        for (r, yr) in y.iter_mut().enumerate() {
            let base = r * ell.k;
            let mut acc = 0.0f32;
            for j in 0..ell.k {
                acc += ell.data[base + j] as f32
                    * xf[ell.cols[base + j] as usize];
            }
            *yr = acc;
        }
    }

    /// y = A x through the ELL kernel semantics (bucket-checked).
    pub fn spmv_ell(&self, ell: &Ell, x: &[f64]) -> Result<Vec<f64>> {
        let meta = self
            .registry
            .pick_ell(ell.n_rows, ell.k)
            .ok_or_else(|| {
                anyhow!("no ELL bucket fits rows={} k={}", ell.n_rows, ell.k)
            })?
            .clone();
        let mut xf = vec![0.0f32; meta.n.max(ell.n_cols)];
        for (i, &v) in x.iter().enumerate() {
            xf[i] = v as f32;
        }
        let mut y = vec![0.0f32; ell.n_rows];
        Self::ell_spmv_f32(ell, &xf, &mut y);
        Ok(y.iter().map(|&v| v as f64).collect())
    }

    /// y = A x through the segmented-kernel semantics (bucket-checked).
    pub fn spmv_seg(&self, csr: &Csr, x: &[f64]) -> Result<Vec<f64>> {
        let nnz = csr.nnz();
        self.registry.pick_seg(nnz, csr.n_rows).ok_or_else(|| {
            anyhow!("no seg bucket fits nnz={nnz} rows={}", csr.n_rows)
        })?;
        let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let mut y = vec![0.0f64; csr.n_rows];
        for (r, yr) in y.iter_mut().enumerate() {
            let (rc, rv) = csr.row(r);
            let mut acc = 0.0f32;
            for (c, v) in rc.iter().zip(rv) {
                acc += *v as f32 * xf[*c as usize];
            }
            *yr = acc as f64;
        }
        Ok(y)
    }

    /// Four normalized power-iteration steps + Rayleigh quotient.
    pub fn power_iter(&self, ell: &Ell, x0: &[f64]) -> Result<(Vec<f64>, f64)> {
        self.registry.pick_power(ell.n_rows, ell.k).ok_or_else(|| {
            anyhow!("no power bucket fits rows={} k={}", ell.n_rows, ell.k)
        })?;
        anyhow::ensure!(
            ell.n_rows == ell.n_cols,
            "power iteration needs a square matrix"
        );
        let mut v: Vec<f32> = x0.iter().map(|&a| a as f32).collect();
        let mut rayleigh = 0.0f32;
        for _ in 0..4 {
            let mut y = vec![0.0f32; ell.n_rows];
            Self::ell_spmv_f32(ell, &v, &mut y);
            // v is unit-norm, so v . Av is the Rayleigh quotient.
            rayleigh = v.iter().zip(&y).map(|(a, b)| a * b).sum();
            let norm = y.iter().map(|a| a * a).sum::<f32>().sqrt();
            if norm > 0.0 {
                for a in &mut y {
                    *a /= norm;
                }
            }
            v = y;
        }
        Ok((v.iter().map(|&a| a as f64).collect(), rayleigh as f64))
    }

    /// Y = A X per-vector through the ELL SpMM kernel semantics.
    pub fn spmm_ell(
        &self,
        ell: &Ell,
        vectors: &[Vec<f64>],
    ) -> Result<Vec<Vec<f64>>> {
        let v = vectors.len();
        anyhow::ensure!(v > 0, "need at least one vector");
        for x in vectors {
            anyhow::ensure!(x.len() == ell.n_cols, "vector length mismatch");
        }
        self.registry.pick_spmm(ell.n_rows, ell.k, v).ok_or_else(|| {
            anyhow!("no SpMM bucket fits rows={} k={} v={v}", ell.n_rows, ell.k)
        })?;
        let mut out = Vec::with_capacity(v);
        for x in vectors {
            let xf: Vec<f32> = x.iter().map(|&a| a as f32).collect();
            let mut y = vec![0.0f32; ell.n_rows];
            Self::ell_spmv_f32(ell, &xf, &mut y);
            out.push(y.iter().map(|&a| a as f64).collect());
        }
        Ok(out)
    }

    /// Route a CSR matrix to the best kernel: ELL when padding is
    /// acceptable, the segmented kernel otherwise (identical routing
    /// to the PJRT build).
    pub fn spmv(&self, csr: &Csr, x: &[f64]) -> Result<Vec<f64>> {
        let k = csr.max_row_nnz();
        let dense_ok = self.registry.pick_ell(csr.n_rows, k).is_some();
        if dense_ok && k > 0 {
            let ell = Ell::from_csr(csr, None)
                .map_err(|e| anyhow!("ell conversion: {e}"))?;
            self.spmv_ell(&ell, x)
        } else {
            self.spmv_seg(csr, x)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Registry parsing is testable without artifacts on disk; the
    // execution paths are covered by `tests/runtime_integration.rs`
    // (which requires `make artifacts`).

    fn toy_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format":"hlo-text","artifacts":[
              {"name":"ell_small","file":"a.hlo.txt","kind":"ell","rows":1024,"k":8,"n":1024},
              {"name":"ell_big","file":"b.hlo.txt","kind":"ell","rows":4096,"k":32,"n":4096},
              {"name":"seg","file":"c.hlo.txt","kind":"seg","rows":4096,"nnz":16384,"n":4096},
              {"name":"pow","file":"d.hlo.txt","kind":"power","rows":4096,"k":16,"n":4096}
            ]}"#,
        )
        .unwrap();
    }

    #[test]
    fn registry_parses_and_picks() {
        let dir = std::env::temp_dir().join("ft2000_registry_test");
        toy_manifest(&dir);
        let reg = Registry::load(&dir).unwrap();
        assert_eq!(reg.artifacts.len(), 4);
        assert_eq!(reg.pick_ell(1000, 8).unwrap().name, "ell_small");
        assert_eq!(reg.pick_ell(1000, 9).unwrap().name, "ell_big");
        assert_eq!(reg.pick_ell(2000, 4).unwrap().name, "ell_big");
        assert!(reg.pick_ell(9999, 4).is_none());
        assert_eq!(reg.pick_seg(100, 100).unwrap().name, "seg");
        assert!(reg.pick_seg(20000, 100).is_none());
        assert_eq!(reg.pick_power(4096, 16).unwrap().name, "pow");
    }

    #[test]
    fn registry_missing_dir_errors() {
        let err = Registry::load("/nonexistent/path/xyz").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
