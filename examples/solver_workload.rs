//! Solver workload: Conjugate Gradient on a 2-D Poisson problem —
//! the kind of scientific application the paper motivates SpMV with —
//! executed on the host and characterized on the simulated FT-2000+.
//!
//! Run: `cargo run --release --example solver_workload [-- grid_side]`

use ft2000_spmv::coordinator::{profile_matrix, ProfileConfig};
use ft2000_spmv::corpus::generators;
use ft2000_spmv::sched::Schedule;
use ft2000_spmv::solver::{cg, CgOptions};
use ft2000_spmv::sparse::Coo;
use ft2000_spmv::util::rng::Pcg32;
use ft2000_spmv::util::table::Table;

fn main() {
    let side: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(96);
    // SPD system: 5-point Laplacian + diagonal shift.
    let lap = generators::stencil(side * side, 5);
    let n = lap.n_rows;
    let mut coo = Coo::new(n, n);
    for r in 0..n {
        let (cols, vals) = lap.row(r);
        for (&c, &v) in cols.iter().zip(vals) {
            coo.push(r, c as usize, v);
        }
        coo.push(r, r, 0.1);
    }
    let a = coo.to_csr();
    let mut rng = Pcg32::new(42);
    let x_true: Vec<f64> = (0..n).map(|_| rng.gen_f64() - 0.5).collect();
    let mut b = vec![0.0; n];
    a.spmv(&x_true, &mut b);
    println!(
        "Poisson system: {n} unknowns, {} nonzeros ({}x{} grid)\n",
        a.nnz(),
        side,
        side
    );

    // --- host solves under different schedules -------------------------
    let mut t = Table::new(
        "CG on this machine (rel_tol 1e-8)",
        &["config", "iters", "converged", "wall SpMV (ms)", "max |x-x*|"],
    );
    for (name, opts) in [
        ("1 thread, CSR", CgOptions::default()),
        (
            "4 threads, CSR",
            CgOptions { threads: 4, ..Default::default() },
        ),
        (
            "4 threads, CSR5",
            CgOptions {
                threads: 4,
                schedule: Schedule::Csr5Tiles { tile_nnz: 256 },
                ..Default::default()
            },
        ),
        (
            "4 threads, CSR + Jacobi",
            CgOptions { threads: 4, jacobi: true, ..Default::default() },
        ),
    ] {
        let r = cg(&a, &b, &opts);
        let err = r
            .x
            .iter()
            .zip(&x_true)
            .map(|(p, q)| (p - q).abs())
            .fold(0.0, f64::max);
        t.row(vec![
            name.into(),
            r.iterations.to_string(),
            r.converged.to_string(),
            format!("{:.2}", r.spmv_seconds * 1e3),
            format!("{err:.2e}"),
        ]);
    }
    t.print();

    // --- simulated per-iteration cost on FT-2000+ -----------------------
    let profile = profile_matrix(&a, "poisson", &ProfileConfig::default());
    let mut t = Table::new(
        "Simulated FT-2000+ cost per CG iteration (1 SpMV dominates)",
        &["threads", "SpMV µs (simulated)", "speedup"],
    );
    for (i, nt) in profile.thread_counts.iter().enumerate() {
        t.row(vec![
            nt.to_string(),
            format!("{:.1}", profile.wall_seconds[i] * 1e6),
            format!("{:.3}x", profile.speedups[i]),
        ]);
    }
    t.print();
}
