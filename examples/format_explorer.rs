//! Format explorer: one matrix across every storage format and
//! schedule — numeric agreement + simulated FT-2000+ scalability.
//!
//! Run: `cargo run --release --example format_explorer [-- <named>]`
//! (named: bone010, exdata_1, conf5_4-8x8-20, debr, appu, asia_osm)

use ft2000_spmv::coordinator::{profile_matrix, ProfileConfig};
use ft2000_spmv::corpus::NamedMatrix;
use ft2000_spmv::exec;
use ft2000_spmv::sched::Schedule;
use ft2000_spmv::sparse::{features::job_var, Csr5, Ell, Hyb};
use ft2000_spmv::util::rng::Pcg32;
use ft2000_spmv::util::table::Table;

fn main() {
    let which = std::env::args().nth(1).unwrap_or_else(|| "exdata_1".into());
    let named = NamedMatrix::ALL
        .into_iter()
        .find(|m| m.name() == which)
        .unwrap_or(NamedMatrix::Exdata1);
    let csr = named.generate();
    let mut rng = Pcg32::new(7);
    let x: Vec<f64> = (0..csr.n_cols).map(|_| rng.gen_f64()).collect();
    println!(
        "exploring {} ({} rows, {} nnz, nnz_max {})\n",
        named.name(),
        csr.n_rows,
        csr.nnz(),
        csr.max_row_nnz()
    );

    // --- numeric agreement across formats ------------------------------
    let mut want = vec![0.0; csr.n_rows];
    csr.spmv(&x, &mut want);
    let mut agree = Table::new(
        "Format numeric agreement (max |err| vs CSR)",
        &["format", "max abs err", "storage note"],
    );
    {
        let c5 = Csr5::from_csr(&csr, 256);
        let mut y = vec![0.0; csr.n_rows];
        c5.spmv(&x, &mut y);
        agree.row(vec![
            "CSR5 (tile 256)".into(),
            format!("{:.2e}", max_err(&want, &y)),
            format!("{} tiles", c5.n_tiles()),
        ]);
    }
    match Ell::from_csr(&csr, None) {
        Ok(ell) => {
            let mut y = vec![0.0; csr.n_rows];
            ell.spmv(&x, &mut y);
            agree.row(vec![
                format!("ELL (K={})", ell.k),
                format!("{:.2e}", max_err(&want, &y)),
                format!("{:.1}% padding", 100.0 * ell.padding_ratio()),
            ]);
        }
        Err(e) => {
            agree.row(vec!["ELL".into(), "-".into(), format!("{e}")]);
        }
    }
    {
        let k = Hyb::auto_k(&csr);
        let h = Hyb::from_csr(&csr, k);
        let mut y = vec![0.0; csr.n_rows];
        h.spmv(&x, &mut y);
        agree.row(vec![
            format!("HYB (k={k})"),
            format!("{:.2e}", max_err(&want, &y)),
            format!("{} nnz in COO tail", h.coo.nnz()),
        ]);
    }
    agree.print();

    // --- schedules: job_var + simulated speedup ------------------------
    let mut sched_t = Table::new(
        "Schedules on the simulated FT-2000+ core-group (4 threads)",
        &["schedule", "job_var", "4t speedup", "host ms (this machine)"],
    );
    for sched in [
        Schedule::CsrRowStatic,
        Schedule::CsrRowBalanced,
        Schedule::Csr5Tiles { tile_nnz: 256 },
        Schedule::CsrDynamic { chunk: 64 },
    ] {
        let part = ft2000_spmv::sched::partition(&csr, sched, 4);
        let jv = job_var(&part.thread_nnz(&csr));
        let cfg = ProfileConfig { schedule: sched, ..Default::default() };
        let p = profile_matrix(&csr, named.name(), &cfg);
        let host = exec::spmv_threaded(&csr, &x, sched, 4);
        sched_t.row(vec![
            sched.name(),
            format!("{jv:.3}"),
            format!("{:.3}x", p.max_speedup()),
            format!("{:.3}", host.wall_seconds * 1e3),
        ]);
    }
    sched_t.print();
    println!(
        "(paper Fig 7: on exdata_1 CSR5 cuts job_var 0.992 -> 0.298 and lifts speedup 1.018x -> 1.468x)"
    );
}

fn max_err(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}
