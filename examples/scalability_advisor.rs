//! Scalability advisor — the paper's "profiling tool" claim (§5.2.3
//! future work) made concrete: profile a matrix, diagnose the dominant
//! scalability bottleneck, apply the recommended optimization, and
//! verify the improvement in the simulator.
//!
//! Run: `cargo run --release --example scalability_advisor [-- <named>|all]`

use ft2000_spmv::coordinator::advisor::{diagnose, Advice};
use ft2000_spmv::coordinator::{profile_matrix, ProfileConfig};
use ft2000_spmv::corpus::NamedMatrix;
use ft2000_spmv::reorder;
use ft2000_spmv::sched::Schedule;
use ft2000_spmv::sparse::Csr;
use ft2000_spmv::util::table::Table;

fn main() {
    let which = std::env::args().nth(1).unwrap_or_else(|| "all".into());
    let targets: Vec<NamedMatrix> = if which == "all" {
        NamedMatrix::ALL.to_vec()
    } else {
        NamedMatrix::ALL
            .into_iter()
            .filter(|m| m.name() == which)
            .collect()
    };
    let mut t = Table::new(
        "Advisor: diagnose -> optimize -> verify (simulated FT-2000+)",
        &["matrix", "baseline 4t", "diagnosis", "optimized 4t", "action"],
    );
    for named in targets {
        let csr = named.generate();
        let base = profile_matrix(&csr, named.name(), &ProfileConfig::default());
        let advice = diagnose(&csr, &base);
        let primary = advice.first().cloned().unwrap_or(Advice::NoActionNeeded);
        let (optimized, action) = apply(&csr, &primary);
        t.row(vec![
            named.name().to_string(),
            format!("{:.3}x", base.max_speedup()),
            format!("{primary:?}"),
            optimized
                .map(|s| format!("{s:.3}x"))
                .unwrap_or_else(|| "-".into()),
            action,
        ]);
    }
    t.print();
}

/// Apply the advised optimization and return the new 4-thread speedup.
fn apply(csr: &Csr, advice: &Advice) -> (Option<f64>, String) {
    match advice {
        Advice::UseCsr5 => {
            let cfg = ProfileConfig {
                schedule: Schedule::Csr5Tiles { tile_nnz: 256 },
                ..Default::default()
            };
            let p = profile_matrix(csr, "csr5", &cfg);
            (Some(p.max_speedup()), "switched to CSR5 tiles".into())
        }
        Advice::UsePrivateL2 => {
            let p = profile_matrix(csr, "priv", &ProfileConfig::private_l2());
            (
                Some(p.max_speedup()),
                "pinned threads to separate core-groups".into(),
            )
        }
        Advice::UseLocalityReorder => {
            let plan = reorder::locality_reorder(csr, 64);
            let fixed = plan.apply(csr);
            let p = profile_matrix(&fixed, "reord", &ProfileConfig::default());
            (Some(p.max_speedup()), "applied locality row reorder".into())
        }
        Advice::FitsInCache | Advice::NoActionNeeded => {
            (None, "none needed".into())
        }
    }
}
