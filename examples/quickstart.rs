//! Quickstart: the whole stack on one small matrix.
//!
//! 1. generate a banded test matrix (corpus);
//! 2. run SpMV natively (exec) and through the AOT-compiled Pallas
//!    kernel on the PJRT runtime — check they agree;
//! 3. run 4 power-iteration steps through the composed L2 graph;
//! 4. simulate 1–4-thread scalability on the FT-2000+ core-group and
//!    print the paper-style profile.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use ft2000_spmv::coordinator::{advisor, profile_matrix, ProfileConfig};
use ft2000_spmv::corpus::generators;
use ft2000_spmv::exec;
use ft2000_spmv::runtime::Runtime;
use ft2000_spmv::sched::Schedule;
use ft2000_spmv::sparse::Ell;
use ft2000_spmv::util::rng::Pcg32;
use ft2000_spmv::util::table::Table;

fn main() -> anyhow::Result<()> {
    let mut rng = Pcg32::new(2019);
    let csr = generators::banded(4096, 7, &mut rng);
    let x: Vec<f64> = (0..csr.n_cols).map(|_| rng.gen_f64()).collect();
    println!(
        "matrix: {} rows, {} nnz (banded FEM-like)\n",
        csr.n_rows,
        csr.nnz()
    );

    // --- native vs PJRT (pallas kernel) -------------------------------
    let native = exec::spmv_sequential(&csr, &x);
    let rt = Runtime::new("artifacts")?;
    let y_pjrt = rt.spmv(&csr, &x)?;
    let max_err = native
        .y
        .iter()
        .zip(&y_pjrt)
        .map(|(a, b)| (a - b).abs() / (1.0 + a.abs()))
        .fold(0.0, f64::max);
    println!(
        "native vs pallas-kernel-on-PJRT: max relative error {max_err:.2e} (platform: {})",
        rt.platform()
    );
    assert!(max_err < 1e-4);

    // --- composed graph: power iteration ------------------------------
    let ell = Ell::from_csr(&csr, None)?;
    let x0 = vec![1.0 / (csr.n_rows as f64).sqrt(); csr.n_rows];
    let (_v, rayleigh) = rt.power_iter(&ell, &x0)?;
    println!("power iteration (4 steps, AOT graph): rayleigh = {rayleigh:.4}\n");

    // --- threaded execution (host) ------------------------------------
    let threaded = exec::spmv_threaded(&csr, &x, Schedule::CsrRowStatic, 4);
    println!(
        "host 4-thread CSR SpMV: {:.3} ms ({:.2} Gflops on this machine)\n",
        threaded.wall_seconds * 1e3,
        threaded.gflops(csr.nnz())
    );

    // --- simulated FT-2000+ scalability --------------------------------
    let profile = profile_matrix(&csr, "banded-4k", &ProfileConfig::default());
    let mut t = Table::new(
        "Simulated FT-2000+ core-group scalability (CSR static)",
        &["threads", "speedup", "Gflops"],
    );
    for (i, nt) in profile.thread_counts.iter().enumerate() {
        t.row(vec![
            nt.to_string(),
            format!("{:.3}x", profile.speedups[i]),
            format!("{:.3}", profile.gflops[i]),
        ]);
    }
    t.print();
    for line in advisor::advise(&csr, &profile) {
        println!("advisor: {line}");
    }
    Ok(())
}
