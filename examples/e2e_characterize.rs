//! End-to-end driver — the full pipeline of the paper on a real
//! (synthetic-corpus) workload:
//!
//! 1. generate the corpus (default: fast = 126 matrices; `--suite
//!    full` = the paper-scale 1008);
//! 2. run the 1–4-thread characterization campaign on the simulated
//!    FT-2000+ core-group (§4.1) → Table 2 + Fig 4;
//! 3. extract the Table-3 features, train the regression forest
//!    (§4.2), report feature importances + the Fig 5 tree;
//! 4. apply the three §5.2 optimizations where the model/advisor says
//!    they apply, and report the improvements (Fig 7, Fig 8, Table 5
//!    headline numbers).
//!
//! Run: `cargo run --release --example e2e_characterize [-- --suite tiny|fast|full]`
//! Results are summarized in EXPERIMENTS.md.

use ft2000_spmv::coordinator::{
    build_dataset, profile_matrix, report, Campaign, ProfileConfig,
};
use ft2000_spmv::corpus::suite::SuiteSpec;
use ft2000_spmv::mlmodel::{Forest, ForestParams};
use ft2000_spmv::sched::Schedule;
use ft2000_spmv::sim::topology::Placement;
use ft2000_spmv::util::stats;
use ft2000_spmv::util::table::Table;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let suite = match args
        .iter()
        .position(|a| a == "--suite")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
    {
        Some("tiny") => SuiteSpec::tiny(),
        Some("full") => SuiteSpec::full(),
        _ => SuiteSpec::fast(),
    };
    let t_start = std::time::Instant::now();

    // ---- Phase 1+2: characterization campaign ------------------------
    println!(
        "== phase 1: characterizing {} matrices (1-4 threads, one core-group) ==\n",
        suite.total()
    );
    let campaign = Campaign::new(suite.clone(), ProfileConfig::default());
    let profiles = campaign.run();
    report::table2_average_speedups(&profiles).print();
    report::fig4_distribution(&profiles).print();
    report::factor_correlations(&profiles).print();

    // ---- Phase 3: regression model ------------------------------------
    println!("== phase 2: regression-tree scalability model (90% train) ==\n");
    let data = build_dataset(&profiles);
    let (train, test) = data.split(0.9, 0x5EED);
    let forest = Forest::fit(&train, ForestParams::default());
    let mut imp = Table::new(
        "Feature importances — what limits SpMV scalability",
        &["rank", "feature", "importance"],
    );
    for (i, (name, v)) in forest.ranked_features().into_iter().enumerate() {
        imp.row(vec![(i + 1).to_string(), name, format!("{v:.4}")]);
    }
    imp.print();
    println!(
        "model quality: train mse {:.4}, held-out mse {:.4}\n",
        forest.mse(&train),
        forest.mse(&test)
    );
    println!("Fig 5 — a tree picked from the regression forest:\n");
    println!("{}", forest.representative_tree(&train).render());

    // ---- Phase 4: guided optimizations --------------------------------
    println!("== phase 3: applying the paper's optimizations ==\n");

    // (a) CSR5 for imbalance-limited matrices (§5.2.1).
    let flagged: Vec<usize> = (0..profiles.len())
        .filter(|&i| profiles[i].derived.job_var >= 0.45)
        .collect();
    if !flagged.is_empty() {
        let csr5_cfg = ProfileConfig {
            schedule: Schedule::Csr5Tiles { tile_nnz: 256 },
            ..Default::default()
        };
        let entries = suite.entries();
        let mut before = Vec::new();
        let mut after = Vec::new();
        for &i in &flagged {
            let m = suite.materialize(&entries[i]);
            before.push(profiles[i].max_speedup());
            after.push(
                profile_matrix(&m.csr, &m.name, &csr5_cfg).max_speedup(),
            );
        }
        println!(
            "(a) CSR5 on {} imbalance-flagged matrices (job_var >= 0.45):\n    avg speedup {:.3}x -> {:.3}x  (paper: 1.632x -> 2.023x)\n",
            flagged.len(),
            stats::mean(&before),
            stats::mean(&after)
        );
    }

    // (b) Private-L2 placement for the whole corpus (§5.2.2).
    let private = Campaign::new(suite.clone(), ProfileConfig::private_l2());
    let private_profiles = private.run();
    let avg_group = stats::mean(
        &profiles.iter().map(|p| p.max_speedup()).collect::<Vec<_>>(),
    );
    let avg_private = stats::mean(
        &private_profiles
            .iter()
            .map(|p| p.max_speedup())
            .collect::<Vec<_>>(),
    );
    println!(
        "(b) private-L2 placement, corpus average 4-thread speedup:\n    {avg_group:.3}x (one core-group) -> {avg_private:.3}x (private L2)  (paper: 1.93x -> 3.40x)\n"
    );

    // (c) Locality-aware reorder on the poor-locality class (§5.2.3).
    let entries = suite.entries();
    let poor: Vec<_> = entries
        .iter()
        .filter(|e| {
            e.class == ft2000_spmv::corpus::MatrixClass::PoorLocality
        })
        .take(8)
        .collect();
    let mut g1_before = Vec::new();
    let mut g1_after = Vec::new();
    let mut g4_before = Vec::new();
    let mut g4_after = Vec::new();
    for e in poor {
        let m = suite.materialize(e);
        let plan = ft2000_spmv::reorder::locality_reorder(&m.csr, 64);
        let fixed = plan.apply(&m.csr);
        let b = profile_matrix(&m.csr, &m.name, &ProfileConfig::default());
        let a = profile_matrix(&fixed, &m.name, &ProfileConfig::default());
        g1_before.push(b.gflops[0]);
        g1_after.push(a.gflops[0]);
        g4_before.push(*b.gflops.last().unwrap());
        g4_after.push(*a.gflops.last().unwrap());
    }
    if !g1_before.is_empty() {
        // Like the paper's Table 5, the win is absolute throughput at
        // every thread count (the reorder speeds the single-thread run
        // too, so the speedup *ratio* can even shrink while Gflops
        // roughly double).
        println!(
            "(c) locality-aware reorder on the poor-locality class (avg Gflops):\n    1 thread : {:.3} -> {:.3} ({:+.1}%)\n    4 threads: {:.3} -> {:.3} ({:+.1}%)   (paper Table 5 @64t: +71.7%)\n",
            stats::mean(&g1_before),
            stats::mean(&g1_after),
            100.0 * (stats::mean(&g1_after) / stats::mean(&g1_before) - 1.0),
            stats::mean(&g4_before),
            stats::mean(&g4_after),
            100.0 * (stats::mean(&g4_after) / stats::mean(&g4_before) - 1.0),
        );
    }

    println!(
        "e2e pipeline complete: {} matrices characterized, model trained, optimizations applied in {:.1}s",
        profiles.len(),
        t_start.elapsed().as_secs_f64()
    );
}
